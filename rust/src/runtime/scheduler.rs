//! Continuous-batching decode scheduler — the rollout-generation hot path
//! (§2.1.2: generation, not verification, is the swarm's dominant compute).
//!
//! The old `SampleEngine::generate` was a static-batch loop: every chunk of
//! `batch_infer` prompts marched in position lockstep, prompts were fed one
//! token per `decode_step`, and a chunk ran until its *longest* row
//! finished, so decode cost scaled with `chunks x longest row`. This module
//! replaces that with a continuously-batched scheduler whose cost scales
//! with total tokens generated:
//!
//! - **Prompt prefill into KV** — an L-token prompt costs one bucketed
//!   `prefill_kv_{T}` call (the smallest compiled `T >= L`) instead of L
//!   decode steps. The artifact computes the prompt forward, returns its
//!   logits/hidden rows (commit-grid rows and the first frontier sample
//!   come from these) and installs the per-layer k/v projections directly
//!   into the persistent decode cache.
//! - **Lane refill** — [`run_continuous`] owns the `batch_infer` decode
//!   lanes. The step a lane's sequence hits EOS / its length limit, the
//!   lane is retired and the next pending prompt is prefilled into it
//!   before the following `decode_step`; occupancy never drops while
//!   prompts are pending. This requires the vectored decode contract:
//!   `pos` is `i32[batch_infer]` (one position per lane), since lanes are
//!   no longer position-synchronized.
//! - **Group-shared prompt KV** — GRPO groups repeat one prompt
//!   `group_size` times by construction (§3.4). A refill wave deduplicates
//!   identical prompts (by [`GenRequest::prompt_key`]), computes each
//!   unique prompt's forward once, and replicates its KV rows across the
//!   group's lanes through the artifact's `lane_src` gather input.
//!
//! **Determinism survives scheduling.** Sampling uses per-rollout RNG
//! streams keyed by `(gen_seed, rollout_index)` ([`rollout_rng`]), and
//! every rollout's observable outputs (tokens, `sampled_probs`, TOPLOC
//! hidden-row commitments, finish reason) are functions of its own prompt,
//! its own stream and the model — never of lane assignment, co-tenants or
//! swarm load. That keeps the paper's §2.3.3 fixed-sampling check
//! *slashable*: a validator can recompute a rollout bit-for-bit without
//! knowing how the worker's scheduler happened to pack it. The kept
//! static-batch path ([`run_static_reference`]) is the equivalence oracle:
//! property tests drive both paths over [`MockBackend`] (a deterministic
//! host-side stand-in model, so the tests run engine-free in CI) and
//! require byte-identical outputs. On real device kernels one fp boundary
//! remains — prompt-position logits/hidden come from the prefill forward
//! rather than per-token decode, and differently-shaped kernels can round
//! differently in the last ulp — which the TOPLOC tolerances absorb
//! (`toploc/mod.rs`); everything the *scheduler* decides (lane
//! assignment, refill order, group sharing) is bit-invariant everywhere.
//!
//! Both paths are generic over [`DecodeBackend`]; the real engine binding
//! lives in [`super::engine::SampleEngine`].

use std::collections::{BTreeMap, VecDeque};

use super::engine::{softmax_prob, Finish, GenOpts, Generation};
use crate::util::rng::Rng;

/// Per-rollout RNG stream: deterministic in `(gen_seed, rollout_index)`
/// and nothing else, so emitted tokens are invariant to lane assignment,
/// chunking and swarm load (§2.3.3 sample determinism).
pub fn rollout_rng(gen_seed: u64, rollout_index: u64) -> Rng {
    Rng::new(gen_seed).fold(rollout_index)
}

/// One generation request (one rollout) for the scheduler paths.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Prompt tokens (BOS-first, no padding, `len < max_seq`).
    pub prompt: Vec<i32>,
    /// This rollout's private sampling stream (see [`rollout_rng`]).
    pub rng: Rng,
    /// Requests with equal keys carry byte-identical prompts (GRPO group
    /// members); a refill wave prefills such prompts once and replicates
    /// the KV rows. Keys never cross a `run_*` call, so any per-call
    /// unique id (e.g. the task's index in the submission) works.
    pub prompt_key: u64,
}

/// Model-shape constants the scheduler needs, decoupled from `ModelSpec`
/// so the mock backend and the property tests run engine-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedSpec {
    /// Decode lanes (`batch_infer`).
    pub lanes: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
}

impl From<&super::spec::ModelSpec> for SchedSpec {
    fn from(s: &super::spec::ModelSpec) -> SchedSpec {
        SchedSpec {
            lanes: s.batch_infer,
            max_seq: s.max_seq,
            vocab: s.vocab,
            d_model: s.d_model,
            pad_id: s.pad_id,
            bos_id: s.bos_id,
            eos_id: s.eos_id,
        }
    }
}

/// What the scheduler needs from a model runtime. The KV cache is owned by
/// the backend (device-resident for the real engine) and is only ever
/// written at a lane's current position before it is read, so lane reuse
/// never leaks a previous occupant's state.
pub trait DecodeBackend {
    fn spec(&self) -> SchedSpec;

    /// One decode step over all lanes: `toks[l]` is fed at position
    /// `pos[l]` of lane `l` (PAD at position 0 for idle lanes). Returns
    /// `(logits, hidden)` as `[lanes * vocab]` / `[lanes * d_model]`.
    fn decode(&mut self, toks: &[i32], pos: &[usize]) -> anyhow::Result<(Vec<f32>, Vec<f32>)>;

    /// Bucket lengths with a compiled `prefill_kv_{T}` artifact, ascending.
    /// Empty = no prompt-prefill support; the scheduler then feeds prompts
    /// through `decode` one token at a time (still with lane refill).
    fn prefill_buckets(&self) -> &[usize];

    /// Prefill `rows` unique prompts (each `len <= t_b`, `rows.len() <=
    /// lanes`) in one bucketed call and install the resulting KV at
    /// positions `0..t_b` of every lane `l` with `assign[l] = Some(row)`
    /// (other lanes' caches untouched). Returns per-unique-row outputs
    /// `(logits [rows * t_b * vocab], hidden [rows * t_b * d_model])`.
    /// Positions at/after a row's true prompt length hold pad-derived
    /// values; the decode path overwrites them before ever attending.
    fn prefill_kv(
        &mut self,
        rows: &[&[i32]],
        t_b: usize,
        assign: &[Option<usize>],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)>;
}

/// Perf accounting for one scheduler run (surfaced per submission in
/// `SwarmStats` — the generation-side mirror of the validator columns).
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    /// `decode_step` artifact invocations.
    pub decode_steps: u64,
    /// `prefill_kv_{T}` artifact invocations.
    pub prefill_calls: u64,
    /// Unique prompt forwards computed across all prefill calls — with
    /// group sharing this tracks tasks-per-wave, not rollouts.
    pub prefill_prompts: u64,
    /// Σ lanes over all decode steps (capacity).
    pub lane_slots: u64,
    /// Σ occupied lanes over all decode steps.
    pub lane_active: u64,
    /// Per decode step: (occupied lanes, requests still pending before the
    /// step) — the refill-invariant trace the scheduler tests assert on.
    pub occupancy: Vec<(u32, u32)>,
    /// Per request: backend-call tick (`decode_steps + prefill_calls` at
    /// that moment) when the request's first *completion* token was
    /// sampled, in request order. Requests that never sample (prompt
    /// already at the length limit) are absent. Serving converts ticks to
    /// wall time for time-to-first-token; pure accounting, no effect on
    /// scheduling or outputs.
    pub first_token_ticks: Vec<(u32, u64)>,
}

impl GenStats {
    /// Fraction of decode-lane slots that carried a live sequence.
    pub fn occupancy_frac(&self) -> f64 {
        self.lane_active as f64 / self.lane_slots.max(1) as f64
    }

    /// The tick (backend calls issued so far) at which request `req`
    /// sampled its first completion token, if it ever did.
    pub fn first_token_tick(&self, req: usize) -> Option<u64> {
        self.first_token_ticks.iter().find(|&&(r, _)| r as usize == req).map(|&(_, t)| t)
    }
}

// ---------------------------------------------------------------------------
// Per-rollout core semantics (shared by both paths)

/// One rollout's accumulator. `observe` is the *exact* per-row semantics
/// of the historical static loop — grid capture, frontier sampling with
/// PAD/BOS masked, unmasked-model probabilities, final-row capture on
/// EOS/limit — so any scheduler that feeds each position's model outputs
/// in order reproduces the reference byte for byte.
struct RolloutCore {
    seq: Vec<i32>,
    prompt_len: usize,
    limit: usize,
    probs: Vec<f32>,
    rows: Vec<(usize, Vec<f32>)>,
    finish: Finish,
    done: bool,
    rng: Rng,
    /// Backend-call tick at which the first completion token was sampled
    /// (TTFT accounting — see [`GenStats::first_token_ticks`]).
    first_tick: Option<u64>,
}

impl RolloutCore {
    fn new(req: &GenRequest, opts: &GenOpts, max_seq: usize) -> RolloutCore {
        RolloutCore {
            prompt_len: req.prompt.len(),
            limit: (req.prompt.len() + opts.max_new).min(max_seq),
            seq: req.prompt.clone(),
            probs: Vec::new(),
            rows: Vec::new(),
            finish: Finish::MaxLen,
            done: false,
            rng: req.rng.clone(),
            first_tick: None,
        }
    }

    /// Process position `pos` given the model's logits/hidden row at that
    /// position. Captures commit-grid rows, and at the frontier
    /// (`pos + 1 == seq.len()`) either finishes on the length limit or
    /// samples the next token from this rollout's private stream. `tick`
    /// is the caller's backend-call count, recorded when the first
    /// completion token appears; it never influences outputs.
    fn observe(
        &mut self,
        pos: usize,
        logits: &[f32],
        hidden: &[f32],
        opts: &GenOpts,
        sp: &SchedSpec,
        tick: u64,
    ) {
        if self.done || pos >= self.seq.len() {
            return;
        }
        // Hidden rows on the commit grid (§2.1.2: every commit_interval
        // tokens, plus the final position per sequence).
        if (pos + 1) % opts.commit_interval == 0 {
            self.rows.push((pos, hidden.to_vec()));
        }
        if pos + 1 != self.seq.len() {
            return; // mid-prompt: capture only
        }
        if self.seq.len() >= self.limit {
            self.done = true;
            self.finish = Finish::MaxLen;
            self.rows.push((pos, hidden.to_vec()));
            return;
        }
        // Special tokens PAD/BOS are never sampled (a PAD inside a
        // sequence would corrupt the validator's prefill segmentation).
        let mut masked = logits.to_vec();
        masked[sp.pad_id as usize] = f32::NEG_INFINITY;
        masked[sp.bos_id as usize] = f32::NEG_INFINITY;
        let (next, _) = self.rng.sample_logits(&masked, opts.temperature);
        // Report the probability under the *unmasked* model distribution —
        // what the TOPLOC validator recomputes.
        let p = softmax_prob(logits, next);
        self.seq.push(next as i32);
        self.probs.push(p);
        if self.first_tick.is_none() {
            self.first_tick = Some(tick);
        }
        if next as i32 == sp.eos_id {
            self.done = true;
            self.finish = Finish::Eos { prob: softmax_prob(logits, sp.eos_id as usize) };
            self.rows.push((pos, hidden.to_vec()));
        }
    }

    fn into_generation(self) -> Generation {
        Generation {
            tokens: self.seq,
            prompt_len: self.prompt_len,
            sampled_probs: self.probs,
            hidden_rows: self.rows,
            finish: self.finish,
        }
    }
}

fn check_requests(requests: &[GenRequest], sp: &SchedSpec) -> anyhow::Result<()> {
    anyhow::ensure!(!requests.is_empty(), "empty request batch");
    for r in requests {
        anyhow::ensure!(
            !r.prompt.is_empty() && r.prompt.len() < sp.max_seq,
            "prompt length {} outside 1..{}",
            r.prompt.len(),
            sp.max_seq
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Static-batch reference path

/// The historical static-batch loop (the `gen-refill off` path and the
/// equivalence oracle): requests run in `lanes`-sized chunks, every row of
/// a chunk marches in position lockstep, prompts are fed one token per
/// decode step, and a chunk runs until its slowest row finishes (drained
/// rows keep burning their lane — exactly the waste [`run_continuous`]
/// removes). Output-equivalent to the continuous path by construction of
/// [`RolloutCore::observe`]; byte-equality is enforced by tests.
pub fn run_static_reference<B: DecodeBackend>(
    backend: &mut B,
    requests: &[GenRequest],
    opts: &GenOpts,
    stats: &mut GenStats,
) -> anyhow::Result<Vec<Generation>> {
    let sp = backend.spec();
    let (b, t, v, d) = (sp.lanes, sp.max_seq, sp.vocab, sp.d_model);
    check_requests(requests, &sp)?;
    let mut out = Vec::with_capacity(requests.len());
    let mut toks = vec![sp.pad_id; b];
    let mut posv = vec![0usize; b];
    for chunk in requests.chunks(b) {
        let mut cores: Vec<RolloutCore> =
            chunk.iter().map(|r| RolloutCore::new(r, opts, t)).collect();
        let mut pos = 0usize;
        loop {
            // Feed the token at `pos` for every row (PAD once finished).
            for l in 0..b {
                toks[l] = sp.pad_id;
                posv[l] = pos;
            }
            for (i, c) in cores.iter().enumerate() {
                if pos < c.seq.len() {
                    toks[i] = c.seq[pos];
                }
            }
            let active = cores.iter().filter(|c| !c.done).count();
            stats.occupancy.push((active as u32, 0));
            stats.lane_slots += b as u64;
            stats.lane_active += active as u64;
            let (logits, hidden) = backend.decode(&toks, &posv)?;
            stats.decode_steps += 1;
            let tick = stats.decode_steps + stats.prefill_calls;
            for (i, c) in cores.iter_mut().enumerate() {
                c.observe(
                    pos,
                    &logits[i * v..(i + 1) * v],
                    &hidden[i * d..(i + 1) * d],
                    opts,
                    &sp,
                    tick,
                );
            }
            pos += 1;
            if pos >= t - 1 || cores.iter().all(|c| c.done && pos >= c.seq.len()) {
                break;
            }
        }
        let base = out.len();
        for (i, c) in cores.iter().enumerate() {
            if let Some(tk) = c.first_tick {
                stats.first_token_ticks.push(((base + i) as u32, tk));
            }
        }
        out.extend(cores.into_iter().map(RolloutCore::into_generation));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Continuous path

/// Continuous-batching generation: prompt prefill into KV, lane refill on
/// EOS/limit, group-shared prompt forwards (module docs). Outputs are in
/// request order and byte-identical to [`run_static_reference`].
pub fn run_continuous<B: DecodeBackend>(
    backend: &mut B,
    requests: &[GenRequest],
    opts: &GenOpts,
    stats: &mut GenStats,
) -> anyhow::Result<Vec<Generation>> {
    run_continuous_prioritized(backend, requests, &[], opts, stats)
}

/// [`run_continuous`] with a priority refill hook (the serve-mode
/// co-tenancy entry point): requests whose `priority` flag is set jump the
/// pending queue, so a user query waiting on time-to-first-token takes the
/// next free lane ahead of pending RL prompts. Priorities reorder *lane
/// admission only* — every rollout's observable outputs are functions of
/// its own prompt and private RNG stream (module docs), so RL rollouts
/// produce byte-identical tokens/probs/commitments whether or not user
/// queries share the batch, and an empty `priority` slice makes this
/// function exactly [`run_continuous`]. Outputs stay in request order.
pub fn run_continuous_prioritized<B: DecodeBackend>(
    backend: &mut B,
    requests: &[GenRequest],
    priority: &[bool],
    opts: &GenOpts,
    stats: &mut GenStats,
) -> anyhow::Result<Vec<Generation>> {
    let sp = backend.spec();
    let (b, t, v, d) = (sp.lanes, sp.max_seq, sp.vocab, sp.d_model);
    check_requests(requests, &sp)?;
    anyhow::ensure!(
        priority.is_empty() || priority.len() == requests.len(),
        "priority slice length {} != {} requests",
        priority.len(),
        requests.len()
    );
    let is_priority = |i: usize| priority.get(i).copied().unwrap_or(false);
    let mut cores: Vec<RolloutCore> =
        requests.iter().map(|r| RolloutCore::new(r, opts, t)).collect();
    // Priority-marked requests first (stable within each class), so the
    // next refill wave admits them ahead of the RL backlog.
    let mut pending: VecDeque<usize> = (0..requests.len())
        .filter(|&i| is_priority(i))
        .chain((0..requests.len()).filter(|&i| !is_priority(i)))
        .collect();
    // lanes[l] = request index occupying lane l; feed[l] = its next
    // position to feed (per-lane `pos` — lanes are not synchronized).
    let mut lanes: Vec<Option<usize>> = vec![None; b];
    let mut feed = vec![0usize; b];
    let mut toks = vec![sp.pad_id; b];
    let mut posv = vec![0usize; b];
    loop {
        refill(
            backend, requests, &mut cores, &mut lanes, &mut feed, &mut pending, opts, &sp, stats,
        )?;
        let active = lanes.iter().filter(|l| l.is_some()).count();
        if active == 0 {
            debug_assert!(pending.is_empty());
            break;
        }
        for l in 0..b {
            match lanes[l] {
                Some(r) => {
                    toks[l] = cores[r].seq[feed[l]];
                    posv[l] = feed[l];
                }
                None => {
                    toks[l] = sp.pad_id;
                    posv[l] = 0;
                }
            }
        }
        stats.occupancy.push((active as u32, pending.len() as u32));
        stats.lane_slots += b as u64;
        stats.lane_active += active as u64;
        let (logits, hidden) = backend.decode(&toks, &posv)?;
        stats.decode_steps += 1;
        let tick = stats.decode_steps + stats.prefill_calls;
        for l in 0..b {
            let Some(r) = lanes[l] else { continue };
            let pos = feed[l];
            let (lg, hd) = (&logits[l * v..(l + 1) * v], &hidden[l * d..(l + 1) * d]);
            cores[r].observe(pos, lg, hd, opts, &sp, tick);
            if cores[r].done {
                lanes[l] = None; // retired the step its sequence ended
            } else if pos + 1 >= t - 1 {
                // The reference loop never feeds position t-1: sequences
                // reaching it stop as MaxLen with no final commit row.
                cores[r].done = true;
                lanes[l] = None;
            } else {
                feed[l] = pos + 1;
            }
        }
    }
    for (i, c) in cores.iter().enumerate() {
        if let Some(tk) = c.first_tick {
            stats.first_token_ticks.push((i as u32, tk));
        }
    }
    Ok(cores.into_iter().map(RolloutCore::into_generation).collect())
}

/// Fill every free lane from the pending queue. With prefill support, a
/// wave of pending prompts is partitioned by covering bucket, identical
/// prompts are deduplicated (computed once, KV replicated across the
/// group's lanes) and each bucket costs one `prefill_kv_{T}` call; a
/// prompt no bucket covers — or all prompts, when no `prefill_kv`
/// artifacts are shipped — falls back to token-by-token feeding through
/// `decode`. Rollouts that finish *during* prefill (EOS on the first
/// sample, limit already met) free their lane immediately, and the loop
/// re-fills it, so occupancy never drops while prompts are pending.
fn refill<B: DecodeBackend>(
    backend: &mut B,
    requests: &[GenRequest],
    cores: &mut [RolloutCore],
    lanes: &mut [Option<usize>],
    feed: &mut [usize],
    pending: &mut VecDeque<usize>,
    opts: &GenOpts,
    sp: &SchedSpec,
    stats: &mut GenStats,
) -> anyhow::Result<()> {
    let (t, v, d) = (sp.max_seq, sp.vocab, sp.d_model);
    loop {
        let free: Vec<usize> =
            (0..lanes.len()).filter(|&l| lanes[l].is_none()).collect();
        if free.is_empty() || pending.is_empty() {
            return Ok(());
        }
        let wave: Vec<usize> =
            (0..free.len()).filter_map(|_| pending.pop_front()).collect();
        // Partition the wave by the cheapest covering prefill bucket;
        // uncovered prompts decode token-by-token from position 0.
        let mut by_bucket: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        let mut uncovered: Vec<usize> = Vec::new();
        let buckets = backend.prefill_buckets().to_vec();
        for &r in &wave {
            match buckets.iter().find(|&&x| x >= requests[r].prompt.len()) {
                Some(&t_b) => by_bucket.entry(t_b).or_default().push(r),
                None => uncovered.push(r),
            }
        }
        let mut free_iter = free.into_iter();
        for r in uncovered {
            // swarmlint: allow(panic-path) — wave construction capped the
            // wave at the free-lane count; exhaustion is a scheduler bug.
            let l = free_iter.next().expect("wave <= free lanes");
            lanes[l] = Some(r);
            feed[l] = 0;
        }
        for (t_b, members) in by_bucket {
            // Unique prompts in first-seen order: group members share a
            // prompt_key, so each task's forward is computed once and its
            // KV rows are replicated across the group's lanes.
            let mut rows: Vec<&[i32]> = Vec::new();
            let mut seen: Vec<(u64, usize)> = Vec::new();
            let mut assign: Vec<Option<usize>> = vec![None; lanes.len()];
            let mut placed: Vec<(usize, usize, usize)> = Vec::new(); // (req, lane, row)
            for &r in &members {
                let key = requests[r].prompt_key;
                let hit = seen
                    .iter()
                    .find(|&&(k, i)| k == key && rows[i] == requests[r].prompt.as_slice())
                    .map(|&(_, i)| i);
                let row = match hit {
                    Some(i) => i,
                    None => {
                        rows.push(&requests[r].prompt);
                        seen.push((key, rows.len() - 1));
                        rows.len() - 1
                    }
                };
                // swarmlint: allow(panic-path) — same wave-size invariant
                // as the uncovered loop above.
                let l = free_iter.next().expect("wave <= free lanes");
                assign[l] = Some(row);
                lanes[l] = Some(r);
                placed.push((r, l, row));
            }
            let (logits, hidden) = backend.prefill_kv(&rows, t_b, &assign)?;
            stats.prefill_calls += 1;
            stats.prefill_prompts += rows.len() as u64;
            let tick = stats.decode_steps + stats.prefill_calls;
            for (r, l, row) in placed {
                let plen = requests[r].prompt.len();
                // Replay the prompt positions from the prefill outputs:
                // commit-grid captures, then the frontier sample at
                // plen-1 — the same observe sequence the reference path
                // runs one decode step at a time.
                for pos in 0..plen {
                    cores[r].observe(
                        pos,
                        &logits[(row * t_b + pos) * v..(row * t_b + pos + 1) * v],
                        &hidden[(row * t_b + pos) * d..(row * t_b + pos + 1) * d],
                        opts,
                        sp,
                        tick,
                    );
                }
                if cores[r].done {
                    lanes[l] = None;
                } else if plen >= t - 1 {
                    // First sampled token sits at position t-1, which the
                    // reference loop never feeds: stop as MaxLen.
                    cores[r].done = true;
                    lanes[l] = None;
                } else {
                    feed[l] = plen;
                }
            }
        }
        // Instantly-finished rollouts freed lanes above; loop to refill.
    }
}

// ---------------------------------------------------------------------------
// Deterministic mock backend (tests + generation_bench)

/// Engine-free stand-in model: logits and hidden rows are pure functions
/// of a lane's token history prefix, so prefill-sourced and decode-sourced
/// outputs are bit-identical — which is exactly the property the scheduler
/// equivalence tests need to check *scheduling* (lane refill, prefill
/// replay, RNG streams) rather than kernel numerics. The per-call cost is
/// `O(lanes * (vocab + d_model))` regardless of how many lanes are live,
/// mirroring a dense device batch, so step counts translate to time.
///
/// EOS pressure grows with completion length at a per-sequence rate, so a
/// mixed workload retires lanes at very different times (the
/// straggler-heavy mix continuous batching exists for).
pub struct MockBackend {
    sp: SchedSpec,
    buckets: Vec<usize>,
    hist: Vec<Vec<i32>>,
    /// EOS-logit pressure per generated token (0.0 = near-never ends).
    pub eos_bias: f32,
}

fn mix(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    h = h.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    h ^ (h >> 33)
}

impl MockBackend {
    pub fn new(sp: SchedSpec, buckets: Vec<usize>, eos_bias: f32) -> MockBackend {
        let hist = vec![Vec::new(); sp.lanes];
        MockBackend { sp, buckets, hist, eos_bias }
    }

    /// Power-of-two buckets from 16 up to and including max_seq (the same
    /// ladder shape the AOT harness emits).
    pub fn default_buckets(max_seq: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut t = 16;
        while t < max_seq {
            out.push(t);
            t *= 2;
        }
        out.push(max_seq);
        out
    }

    fn row(&self, hist: &[i32]) -> (Vec<f32>, Vec<f32>) {
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        for &t in hist {
            h = (h ^ (t as u32 as u64)).wrapping_mul(0x1000_0000_01B3);
        }
        // Per-sequence EOS rate from the first few tokens: different
        // rollouts finish at very different lengths (stragglers).
        let head = hist.iter().take(4).fold(0u64, |a, &t| {
            (a ^ (t as u32 as u64)).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        });
        let rate = 0.5 + (mix(head) % 1000) as f32 / 666.0; // [0.5, 2.0)
        let mut logits = vec![0.0f32; self.sp.vocab];
        for (j, l) in logits.iter_mut().enumerate() {
            *l = (mix(h ^ (j as u64).wrapping_mul(0x9E37_79B9)) % 4000) as f32 / 1000.0 - 2.0;
        }
        logits[self.sp.eos_id as usize] += self.eos_bias * rate * hist.len() as f32;
        let mut hidden = vec![0.0f32; self.sp.d_model];
        for (k, x) in hidden.iter_mut().enumerate() {
            *x = (mix(h ^ (k as u64).wrapping_mul(0x85EB_CA6B)) % 2000) as f32 / 1000.0 - 1.0;
        }
        (logits, hidden)
    }
}

impl DecodeBackend for MockBackend {
    fn spec(&self) -> SchedSpec {
        self.sp
    }

    fn decode(&mut self, toks: &[i32], pos: &[usize]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (b, v, d) = (self.sp.lanes, self.sp.vocab, self.sp.d_model);
        anyhow::ensure!(toks.len() == b && pos.len() == b, "lane-shaped inputs required");
        let mut logits = vec![0.0f32; b * v];
        let mut hidden = vec![0.0f32; b * d];
        for l in 0..b {
            // Writing at pos then attending to <= pos means the effective
            // history is the prefix through pos; anything the scheduler
            // left beyond it is stale garbage a real cache would mask, so
            // model it by truncation. Feeding past the written frontier
            // would *read* garbage — that is a scheduler bug, so error.
            anyhow::ensure!(
                pos[l] <= self.hist[l].len(),
                "lane {l} feeds position {} past its KV frontier {}",
                pos[l],
                self.hist[l].len()
            );
            self.hist[l].truncate(pos[l]);
            self.hist[l].push(toks[l]);
            let (lg, hd) = self.row(&self.hist[l]);
            logits[l * v..(l + 1) * v].copy_from_slice(&lg);
            hidden[l * d..(l + 1) * d].copy_from_slice(&hd);
        }
        Ok((logits, hidden))
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill_kv(
        &mut self,
        rows: &[&[i32]],
        t_b: usize,
        assign: &[Option<usize>],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (v, d) = (self.sp.vocab, self.sp.d_model);
        anyhow::ensure!(rows.len() <= self.sp.lanes, "more unique rows than lanes");
        anyhow::ensure!(assign.len() == self.sp.lanes, "lane-shaped assign required");
        for r in rows {
            anyhow::ensure!(r.len() <= t_b, "prompt longer than bucket {t_b}");
        }
        let mut logits = vec![0.0f32; rows.len() * t_b * v];
        let mut hidden = vec![0.0f32; rows.len() * t_b * d];
        for (ri, r) in rows.iter().enumerate() {
            for pos in 0..r.len() {
                let (lg, hd) = self.row(&r[..=pos]);
                logits[(ri * t_b + pos) * v..(ri * t_b + pos + 1) * v].copy_from_slice(&lg);
                hidden[(ri * t_b + pos) * d..(ri * t_b + pos + 1) * d].copy_from_slice(&hd);
            }
        }
        for (l, a) in assign.iter().enumerate() {
            if let Some(ri) = *a {
                anyhow::ensure!(ri < rows.len(), "assign row out of range");
                self.hist[l] = rows[ri].to_vec();
            }
        }
        Ok((logits, hidden))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp() -> SchedSpec {
        SchedSpec { lanes: 4, max_seq: 64, vocab: 16, d_model: 8, pad_id: 0, bos_id: 1, eos_id: 2 }
    }

    fn reqs(n: usize, seed: u64) -> Vec<GenRequest> {
        let mut r = Rng::new(seed);
        (0..n)
            .map(|i| {
                let len = 1 + r.usize(10);
                let mut prompt = vec![1i32];
                prompt.extend((1..len).map(|_| 3 + r.usize(12) as i32));
                let rng = rollout_rng(seed ^ 0x5EED, i as u64);
                GenRequest { prompt, rng, prompt_key: i as u64 }
            })
            .collect()
    }

    #[test]
    fn rollout_rng_streams_are_distinct_and_stable() {
        let mut a = rollout_rng(7, 0);
        let mut a2 = rollout_rng(7, 0);
        let mut b = rollout_rng(7, 1);
        assert_eq!(a.next_u64(), a2.next_u64());
        assert_ne!(rollout_rng(7, 0).next_u64(), b.next_u64());
        assert_ne!(rollout_rng(8, 0).next_u64(), rollout_rng(7, 0).next_u64());
    }

    #[test]
    fn continuous_matches_static_reference() {
        let sp = sp();
        let opts = GenOpts { max_new: 20, temperature: 1.0, commit_interval: 8 };
        let requests = reqs(9, 3);
        let mut st = GenStats::default();
        let mut ct = GenStats::default();
        let a = run_static_reference(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &opts,
            &mut st,
        )
        .unwrap();
        let b = run_continuous(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &opts,
            &mut ct,
        )
        .unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.sampled_probs, y.sampled_probs);
            assert_eq!(x.hidden_rows, y.hidden_rows);
            assert_eq!(x.finish, y.finish);
        }
        assert!(ct.prefill_calls > 0);
        assert!(ct.decode_steps <= st.decode_steps);
    }

    #[test]
    fn prioritized_with_no_priorities_is_run_continuous() {
        let sp = sp();
        let opts = GenOpts { max_new: 20, temperature: 1.0, commit_interval: 8 };
        let requests = reqs(9, 11);
        let mut sa = GenStats::default();
        let mut sb = GenStats::default();
        let a = run_continuous(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &opts,
            &mut sa,
        )
        .unwrap();
        let b = run_continuous_prioritized(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &[],
            &opts,
            &mut sb,
        )
        .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.sampled_probs, y.sampled_probs);
            assert_eq!(x.hidden_rows, y.hidden_rows);
        }
        assert_eq!(sa.decode_steps, sb.decode_steps);
        assert_eq!(sa.occupancy, sb.occupancy);
        assert_eq!(sa.first_token_ticks, sb.first_token_ticks);
    }

    #[test]
    fn priority_request_jumps_the_refill_queue() {
        // 9 requests, 4 lanes: the last request normally waits for a lane.
        // Marked priority, it must ride the *first* refill wave — its first
        // token appears no later than any unprioritized request's.
        let sp = sp();
        let opts = GenOpts { max_new: 20, temperature: 1.0, commit_interval: 8 };
        let requests = reqs(9, 3);
        let mut priority = vec![false; 9];
        priority[8] = true;
        let mut plain = GenStats::default();
        run_continuous(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &opts,
            &mut plain,
        )
        .unwrap();
        let mut pri = GenStats::default();
        run_continuous_prioritized(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &priority,
            &opts,
            &mut pri,
        )
        .unwrap();
        let tick8 = pri.first_token_tick(8).unwrap();
        for i in 0..8 {
            assert!(tick8 <= pri.first_token_tick(i).unwrap(), "request {i} beat the query");
        }
        assert!(tick8 < plain.first_token_tick(8).unwrap(), "priority did not shorten TTFT");
        // A bad priority slice is rejected, not misapplied.
        assert!(run_continuous_prioritized(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &[true],
            &opts,
            &mut GenStats::default(),
        )
        .is_err());
    }

    #[test]
    fn rl_outputs_invariant_under_serve_cotenancy() {
        // The serving contract (§2.3.3 extended to co-tenancy): adding a
        // priority user query to a batch must not change any RL rollout's
        // observable outputs — tokens, probs, commit rows, finish — even
        // though every lane assignment shifts.
        let sp = sp();
        let opts = GenOpts { max_new: 20, temperature: 1.0, commit_interval: 8 };
        let rl = reqs(8, 5);
        let solo = run_continuous(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &rl,
            &opts,
            &mut GenStats::default(),
        )
        .unwrap();
        let mut mixed_reqs = rl.clone();
        mixed_reqs.push(GenRequest {
            prompt: vec![1, 7, 9, 4],
            rng: Rng::new(0xD00D),
            prompt_key: 1000,
        });
        let mut priority = vec![false; 9];
        priority[8] = true;
        let mixed = run_continuous_prioritized(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &mixed_reqs,
            &priority,
            &opts,
            &mut GenStats::default(),
        )
        .unwrap();
        for (x, y) in solo.iter().zip(mixed.iter().take(8)) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.sampled_probs, y.sampled_probs);
            assert_eq!(x.hidden_rows, y.hidden_rows);
            assert_eq!(x.finish, y.finish);
        }
        assert!(mixed[8].tokens.len() > mixed[8].prompt_len, "query produced no completion");
    }

    #[test]
    fn first_token_ticks_cover_all_sampling_requests() {
        let sp = sp();
        let opts = GenOpts { max_new: 20, temperature: 1.0, commit_interval: 8 };
        let requests = reqs(9, 3);
        let mut stats = GenStats::default();
        run_continuous(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &opts,
            &mut stats,
        )
        .unwrap();
        // Every request here has room to sample at least one token.
        for i in 0..9 {
            let t = stats.first_token_tick(i).unwrap();
            assert!(t >= 1 && t <= stats.decode_steps + stats.prefill_calls);
        }
        // The static path records them too (same observe semantics).
        let mut st = GenStats::default();
        run_static_reference(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.3),
            &requests,
            &opts,
            &mut st,
        )
        .unwrap();
        assert_eq!(st.first_token_ticks.len(), 9);
    }

    #[test]
    fn mock_rejects_feeding_past_frontier() {
        let sp = sp();
        let mut m = MockBackend::new(sp, vec![], 0.0);
        // Position 1 before position 0 was ever written.
        let err = m.decode(&[3, 0, 0, 0], &[1, 0, 0, 0]).unwrap_err();
        assert!(err.to_string().contains("KV frontier"), "{err}");
    }

    #[test]
    fn empty_and_oversized_prompts_rejected() {
        let sp = sp();
        let opts = GenOpts::default();
        let mut m = MockBackend::new(sp, vec![], 0.0);
        let bad = vec![GenRequest { prompt: vec![], rng: Rng::new(1), prompt_key: 0 }];
        assert!(run_continuous(&mut m, &bad, &opts, &mut GenStats::default()).is_err());
        let long =
            vec![GenRequest { prompt: vec![1; sp.max_seq], rng: Rng::new(1), prompt_key: 0 }];
        assert!(run_continuous(&mut m, &long, &opts, &mut GenStats::default()).is_err());
        assert!(run_static_reference(&mut m, &[], &opts, &mut GenStats::default()).is_err());
    }
}
