//! `artifacts/<size>/spec.json` — the contract between the Python AOT
//! compiler (L1/L2) and the Rust runtime (L3). Parsed with the in-tree
//! JSON substrate; no Python anywhere near the request path.

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32" | "u32"
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub batch_train: usize,
    pub batch_infer: usize,
    pub n_params: usize,
    pub param_specs: Vec<(String, Vec<usize>)>,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub hp_layout: Vec<String>,
    pub metrics_layout: Vec<String>,
    pub toploc_interval: usize,
    pub toploc_topk: usize,
    pub artifacts: Vec<(String, ArtifactMeta)>,
}

fn sig_list(v: &Json) -> anyhow::Result<Vec<TensorSig>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("signature not an array"))?
        .iter()
        .map(|e| {
            Ok(TensorSig {
                name: e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                shape: e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                dtype: e.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
            })
        })
        .collect()
}

impl ModelSpec {
    pub fn parse(text: &str) -> anyhow::Result<ModelSpec> {
        let j = Json::parse(text)?;
        let model = j.get("model").ok_or_else(|| anyhow::anyhow!("missing model"))?;
        let g = |k: &str| -> anyhow::Result<usize> {
            model.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow::anyhow!("missing model.{k}"))
        };
        let specials = j.get("special_tokens").ok_or_else(|| anyhow::anyhow!("missing special_tokens"))?;
        let strs = |k: &str| -> Vec<String> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
                .unwrap_or_default()
        };
        let mut artifacts = Vec::new();
        if let Some(arts) = j.get("artifacts").and_then(Json::as_obj) {
            for (name, meta) in arts {
                artifacts.push((
                    name.clone(),
                    ArtifactMeta {
                        file: meta.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
                        inputs: sig_list(meta.get("inputs").unwrap_or(&Json::Null))?,
                        outputs: sig_list(meta.get("outputs").unwrap_or(&Json::Null))?,
                    },
                ));
            }
        }
        let param_specs = j
            .get("param_specs")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|e| {
                        (
                            e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                            e.get("shape")
                                .and_then(Json::as_arr)
                                .map(|s| s.iter().filter_map(Json::as_usize).collect())
                                .unwrap_or_default(),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();

        Ok(ModelSpec {
            name: model.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            max_seq: g("max_seq")?,
            vocab: g("vocab")?,
            batch_train: g("batch_train")?,
            batch_infer: g("batch_infer")?,
            n_params: j.get("n_params").and_then(Json::as_usize).unwrap_or(0),
            param_specs,
            pad_id: specials.get("pad").and_then(Json::as_f64).unwrap_or(0.0) as i32,
            bos_id: specials.get("bos").and_then(Json::as_f64).unwrap_or(1.0) as i32,
            eos_id: specials.get("eos").and_then(Json::as_f64).unwrap_or(2.0) as i32,
            hp_layout: strs("hp_layout"),
            metrics_layout: strs("metrics_layout"),
            toploc_interval: j.path(&["toploc", "interval"]).and_then(Json::as_usize).unwrap_or(32),
            // Floor at the verifier's minimum row width: commit rows
            // narrower than MIN_OVERLAP are rejected as forged-shaped
            // (toploc::commitment), so honest builders must never emit
            // them, whatever the spec says. (topk_abs itself clamps to
            // d_model, which covers degenerate tiny models.)
            toploc_topk: j
                .path(&["toploc", "topk"])
                .and_then(Json::as_usize)
                .unwrap_or(8)
                .max(crate::toploc::commitment::MIN_OVERLAP),
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| m)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in spec"))
    }

    /// Sequence lengths of the available validator prefill artifacts:
    /// bucketed `prefill_{T}` variants plus the full-frame `prefill` at
    /// `max_seq`, ascending. The AOT harness may ship any subset of bucket
    /// lengths; [`ModelSpec::prefill_artifact_for`] picks the cheapest one
    /// covering each request.
    pub fn prefill_lengths(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .iter()
            .filter_map(|(name, _)| {
                if name == "prefill" {
                    Some(self.max_seq)
                } else {
                    name.strip_prefix("prefill_").and_then(|t| t.parse().ok())
                }
            })
            .filter(|&t| t > 0 && t <= self.max_seq)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Name + padded length of the cheapest compiled prefill artifact
    /// covering `seq_len` (the shortest `prefill_{T}` with `T >= seq_len`,
    /// falling back to the full `prefill` frame).
    pub fn prefill_artifact_for(&self, seq_len: usize) -> anyhow::Result<(String, usize)> {
        let t = self
            .prefill_lengths()
            .into_iter()
            .find(|&t| t >= seq_len)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no prefill artifact covers seq_len {seq_len} (max_seq {})",
                    self.max_seq
                )
            })?;
        let name = if t == self.max_seq && self.artifact("prefill").is_ok() {
            "prefill".to_string()
        } else {
            format!("prefill_{t}")
        };
        Ok((name, t))
    }

    /// Sequence lengths of the available *generation-side* prompt-prefill
    /// artifacts (`prefill_kv_{T}`), ascending. These are distinct from
    /// the validator's `prefill_{T}` ladder: they additionally take the
    /// decode KV cache plus lane-routing inputs and install the prompt's
    /// per-layer k/v projections into assigned lanes.
    pub fn prefill_kv_lengths(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .iter()
            .filter_map(|(name, _)| name.strip_prefix("prefill_kv_").and_then(|t| t.parse().ok()))
            .filter(|&t| t > 0 && t <= self.max_seq)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Does `decode_step` use the vectored per-lane position contract
    /// (`pos: i32[batch_infer]`)? The continuous scheduler retires and
    /// refills lanes independently, so lanes are not position-synchronized;
    /// artifacts generated before that contract carry a scalar `pos` and
    /// only support the static reference path.
    pub fn decode_pos_per_lane(&self) -> bool {
        self.artifact("decode_step")
            .ok()
            .and_then(|m| m.inputs.iter().find(|s| s.name == "pos"))
            .map(|s| s.shape == vec![self.batch_infer])
            .unwrap_or(false)
    }

    /// Can the continuous-batching generation path run on these artifacts?
    /// Needs the vectored-`pos` decode contract and at least one
    /// `prefill_kv_{T}` bucket; otherwise the runtime falls back to the
    /// static reference path (regenerate with `make artifacts`).
    pub fn supports_continuous(&self) -> bool {
        self.decode_pos_per_lane() && !self.prefill_kv_lengths().is_empty()
    }

    /// Total bytes of one parameter set (f32) — what SHARDCAST broadcasts.
    pub fn params_bytes(&self) -> usize {
        self.n_params * 4
    }

    /// Index of a named metric in the grpo_step metrics vector.
    pub fn metric_idx(&self, name: &str) -> usize {
        self.metrics_layout.iter().position(|m| m == name).expect("unknown metric")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"name": "nano", "d_model": 64, "n_layers": 2, "n_heads": 2,
                "max_seq": 256, "vocab": 64, "batch_train": 8, "batch_infer": 16,
                "grpo_block_rows": 8, "attn_block_q": 64, "attn_block_k": 128},
      "n_params": 120064,
      "param_specs": [{"name": "tok_emb", "shape": [64, 64]}],
      "special_tokens": {"pad": 0, "bos": 1, "eos": 2},
      "adam": {"b1": 0.9, "b2": 0.95, "eps": 1e-8},
      "hp_layout": ["lr", "grad_clip", "eps", "delta", "kl_coef", "ent_coef", "r0", "r1"],
      "metrics_layout": ["loss", "gnorm", "clipfrac", "entropy", "kl", "ratio_max", "obj_mean"],
      "toploc": {"interval": 32, "topk": 8},
      "artifacts": {
        "init": {"file": "init.hlo.txt",
                 "inputs": [{"name": "seed", "shape": [], "dtype": "u32"}],
                 "outputs": [{"name": "param:tok_emb", "shape": [64, 64], "dtype": "f32"}]}
      }
    }"#;

    #[test]
    fn prefill_artifact_selection() {
        let mut s = ModelSpec::parse(SAMPLE).unwrap();
        let meta = s.artifacts[0].1.clone();
        // Only the full frame shipped: everything resolves to it.
        s.artifacts.push(("prefill".to_string(), meta.clone()));
        assert_eq!(s.prefill_lengths(), vec![256]);
        assert_eq!(s.prefill_artifact_for(10).unwrap(), ("prefill".to_string(), 256));
        assert_eq!(s.prefill_artifact_for(256).unwrap(), ("prefill".to_string(), 256));
        // Bucketed variants: cheapest covering length wins; junk and
        // over-length names are ignored.
        s.artifacts.push(("prefill_64".to_string(), meta.clone()));
        s.artifacts.push(("prefill_128".to_string(), meta.clone()));
        s.artifacts.push(("prefill_9999".to_string(), meta.clone()));
        s.artifacts.push(("prefill_x".to_string(), meta));
        assert_eq!(s.prefill_lengths(), vec![64, 128, 256]);
        assert_eq!(s.prefill_artifact_for(10).unwrap(), ("prefill_64".to_string(), 64));
        assert_eq!(s.prefill_artifact_for(64).unwrap(), ("prefill_64".to_string(), 64));
        assert_eq!(s.prefill_artifact_for(65).unwrap(), ("prefill_128".to_string(), 128));
        assert_eq!(s.prefill_artifact_for(200).unwrap(), ("prefill".to_string(), 256));
        assert!(s.prefill_artifact_for(257).is_err());
    }

    #[test]
    fn continuous_support_detection() {
        let mut s = ModelSpec::parse(SAMPLE).unwrap();
        let meta = s.artifacts[0].1.clone();
        // Seed-era artifacts: no prefill_kv ladder, no decode_step.
        assert!(s.prefill_kv_lengths().is_empty());
        assert!(!s.decode_pos_per_lane());
        assert!(!s.supports_continuous());
        // prefill_kv ladder alone is not enough (junk/overlong ignored)...
        s.artifacts.push(("prefill_kv_64".to_string(), meta.clone()));
        s.artifacts.push(("prefill_kv_128".to_string(), meta.clone()));
        s.artifacts.push(("prefill_kv_9999".to_string(), meta.clone()));
        s.artifacts.push(("prefill_kv_x".to_string(), meta.clone()));
        assert_eq!(s.prefill_kv_lengths(), vec![64, 128]);
        // ...and the generation ladder must not leak into the validator's.
        assert!(s.prefill_lengths().is_empty());
        assert!(!s.supports_continuous());
        // Legacy scalar-pos decode_step: still static-only.
        let mut legacy = meta.clone();
        legacy.inputs = vec![TensorSig { name: "pos".into(), shape: vec![], dtype: "i32".into() }];
        s.artifacts.push(("decode_step".to_string(), legacy));
        assert!(!s.decode_pos_per_lane());
        assert!(!s.supports_continuous());
        // Vectored per-lane pos ([batch_infer]) completes the contract.
        s.artifacts.retain(|(n, _)| n != "decode_step");
        let mut vectored = meta;
        vectored.inputs =
            vec![TensorSig { name: "pos".into(), shape: vec![16], dtype: "i32".into() }];
        s.artifacts.push(("decode_step".to_string(), vectored));
        assert!(s.decode_pos_per_lane());
        assert!(s.supports_continuous());
    }

    #[test]
    fn parses_sample() {
        let s = ModelSpec::parse(SAMPLE).unwrap();
        assert_eq!(s.name, "nano");
        assert_eq!(s.d_model, 64);
        assert_eq!(s.toploc_topk, 8);
        // A topk below the verifier's minimum row width is floored, so
        // honest builders never emit commit rows the validator rejects.
        let narrow = ModelSpec::parse(&SAMPLE.replace("\"topk\": 8", "\"topk\": 2")).unwrap();
        assert_eq!(narrow.toploc_topk, crate::toploc::commitment::MIN_OVERLAP);
        assert_eq!(s.params_bytes(), 120064 * 4);
        assert_eq!(s.metric_idx("kl"), 4);
        let a = s.artifact("init").unwrap();
        assert_eq!(a.inputs[0].dtype, "u32");
        assert_eq!(a.outputs[0].numel(), 4096);
        assert!(s.artifact("nope").is_err());
    }
}
