//! Threaded HTTP/1.1 server with the protections the paper configures on
//! its nginx relays (§2.2.1): per-peer token-bucket rate limiting and a
//! dynamic allowlist firewall (the UFW analogue), plus optional bandwidth
//! shaping to emulate WAN links on loopback.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::faults::{Fault, FaultInjector, Partition};
use super::{parse_request, write_response, Request, Response};
use crate::util::metrics::Counter;

pub type Handler = dyn Fn(&Request) -> Response + Send + Sync + 'static;

#[derive(Clone)]
pub struct ServerConfig {
    /// Requests per second allowed per peer key (0 = unlimited). The paper
    /// rate-limits per IP on nginx; loopback peers all share an IP, so the
    /// key is the `x-node-id` header when present, else the peer address.
    pub rate_limit_rps: f64,
    pub rate_limit_burst: f64,
    /// When non-empty, only these node ids / peers may connect (UFW-style
    /// dynamic firewall, §2.2.1).
    pub firewall_enabled: bool,
    /// Simulated egress bandwidth in bytes/sec (0 = unshaped). Applied per
    /// response to emulate the 590 Mb/s-class WAN links of §4.2.
    pub egress_bytes_per_sec: u64,
    pub max_body: usize,
    pub worker_threads: usize,
    /// Deterministic fault plane (chaos testing): when set, each incoming
    /// request consumes the injector's next scheduled fault — refused,
    /// hung, 5xx'd, truncated or delayed before the handler ever runs.
    pub faults: Option<Arc<FaultInjector>>,
    /// Netsplit plane: requests whose `x-node-id` is severed from this
    /// server's [`ServerConfig::domain`] by a live [`Partition`] cut are
    /// dropped without a response (the client sees a refused peer).
    pub partition: Option<Arc<Partition>>,
    /// This server's partition domain (matched as the `dst` side of
    /// cuts). Empty = matches only wildcard cuts.
    pub domain: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            rate_limit_rps: 0.0,
            rate_limit_burst: 20.0,
            firewall_enabled: false,
            egress_bytes_per_sec: 0,
            max_body: 256 << 20,
            worker_threads: 4,
            faults: None,
            partition: None,
            domain: String::new(),
        }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

#[derive(Default)]
pub struct ServerStats {
    pub requests: Counter,
    pub rejected_rate: Counter,
    pub rejected_firewall: Counter,
    pub bytes_out: Counter,
}

pub struct HttpServer {
    pub addr: String,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    allowlist: Arc<RwLock<Vec<String>>>,
    pub stats: Arc<ServerStats>,
    /// Dynamically adjustable egress shaping (perf experiments tune this).
    egress: Arc<AtomicU64>,
}

impl HttpServer {
    /// Bind to `127.0.0.1:0` (ephemeral port) and serve `handler`.
    pub fn start<H>(cfg: ServerConfig, handler: H) -> anyhow::Result<HttpServer>
    where
        H: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        HttpServer::serve(TcpListener::bind("127.0.0.1:0")?, cfg, handler)
    }

    /// Bind a *specific* address — the restart path: a service that died
    /// can come back on the port its clients already hold (the churn
    /// harness restarts the orchestrator this way).
    pub fn start_on<H>(addr: &str, cfg: ServerConfig, handler: H) -> anyhow::Result<HttpServer>
    where
        H: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        HttpServer::serve(TcpListener::bind(addr)?, cfg, handler)
    }

    fn serve<H>(listener: TcpListener, cfg: ServerConfig, handler: H) -> anyhow::Result<HttpServer>
    where
        H: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let allowlist: Arc<RwLock<Vec<String>>> = Arc::new(RwLock::new(Vec::new()));
        let stats = Arc::new(ServerStats::default());
        let egress = Arc::new(AtomicU64::new(cfg.egress_bytes_per_sec));
        let handler: Arc<Handler> = Arc::new(handler);
        let buckets: Arc<Mutex<BTreeMap<String, Bucket>>> = Arc::new(Mutex::new(BTreeMap::new()));

        let accept_thread = {
            let stop = Arc::clone(&stop);
            let cfg = cfg.clone();
            let allowlist = Arc::clone(&allowlist);
            let stats = Arc::clone(&stats);
            let egress = Arc::clone(&egress);
            let pool = crate::util::pool::ThreadPool::new(cfg.worker_threads);
            std::thread::Builder::new().name("i2-http-accept".into()).spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let handler = Arc::clone(&handler);
                            let cfg = cfg.clone();
                            let allowlist = Arc::clone(&allowlist);
                            let stats = Arc::clone(&stats);
                            let egress = Arc::clone(&egress);
                            let buckets = Arc::clone(&buckets);
                            pool.submit(move || {
                                handle_conn(stream, &cfg, &handler, &allowlist, &stats, &egress, &buckets);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_micros(300));
                        }
                        Err(_) => break,
                    }
                }
            })?
        };

        Ok(HttpServer { addr, cfg, stop, accept_thread: Some(accept_thread), allowlist, stats, egress })
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Replace the firewall allowlist with the currently active node set
    /// (the orchestrator pushes this on pool membership changes, §2.2.1).
    pub fn set_allowlist(&self, nodes: Vec<String>) {
        *self.allowlist.write().unwrap() = nodes;
    }

    pub fn set_egress_bytes_per_sec(&self, bps: u64) {
        self.egress.store(bps, Ordering::SeqCst);
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn(
    mut stream: TcpStream,
    cfg: &ServerConfig,
    handler: &Arc<Handler>,
    allowlist: &RwLock<Vec<String>>,
    stats: &ServerStats,
    egress: &AtomicU64,
    buckets: &Mutex<BTreeMap<String, Bucket>>,
) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(20)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    // Fault plane: one scheduled fault per connection, consumed up front so
    // `Refuse` can drop the socket without reading a byte (what a crashed
    // peer looks like from the client side).
    let fault = cfg.faults.as_ref().and_then(|f| f.next_fault());
    if fault == Some(Fault::Refuse) {
        return;
    }
    let req = match parse_request(&mut stream, cfg.max_body) {
        Ok(r) => r,
        Err(_) => return,
    };
    stats.requests.inc();
    match fault {
        Some(Fault::Hang { ms }) => {
            // Accept-then-hang: read the request, never answer, drop.
            std::thread::sleep(Duration::from_millis(ms));
            return;
        }
        Some(Fault::Status(code)) => {
            let _ = write_response(&mut stream, &Response::error(code, "fault injection"));
            return;
        }
        Some(Fault::Delay { ms }) => std::thread::sleep(Duration::from_millis(ms)),
        _ => {}
    }
    let key = req.header("x-node-id").map(|s| s.to_string()).unwrap_or_else(|| req.peer.clone());

    // Netsplit plane: a live partition cut between the requester's domain
    // and this server's drops the socket, response-less — a severed WAN
    // link, not an HTTP error. (The request must be read first: the src
    // identity rides the x-node-id header.)
    if let Some(p) = &cfg.partition {
        if p.severed(&key, &cfg.domain) {
            p.refused.inc();
            return;
        }
    }

    // Firewall: only currently-active pool members get through.
    if cfg.firewall_enabled {
        let allowed = allowlist.read().unwrap().iter().any(|n| *n == key);
        if !allowed {
            stats.rejected_firewall.inc();
            let _ = write_response(&mut stream, &Response::error(403, "firewall: not in compute pool"));
            return;
        }
    }

    // Token-bucket rate limit per node id.
    if cfg.rate_limit_rps > 0.0 {
        let mut map = buckets.lock().unwrap();
        let b = map
            .entry(key)
            .or_insert_with(|| Bucket { tokens: cfg.rate_limit_burst, last: Instant::now() });
        let dt = b.last.elapsed().as_secs_f64();
        b.last = Instant::now();
        b.tokens = (b.tokens + dt * cfg.rate_limit_rps).min(cfg.rate_limit_burst);
        if b.tokens < 1.0 {
            drop(map);
            stats.rejected_rate.inc();
            let _ = write_response(&mut stream, &Response::error(429, "rate limited"));
            return;
        }
        b.tokens -= 1.0;
    }

    let resp = handler(&req);
    stats.bytes_out.add(resp.body.len() as u64);

    if fault == Some(Fault::Truncate) {
        // Mid-body truncation: the head promises the full content-length,
        // the body stops halfway, the socket drops — the client's
        // `read_exact` must surface a short read, not hand back a prefix.
        let head = format!(
            "HTTP/1.1 {} {}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
            resp.status,
            Response::status_text(resp.status),
            resp.body.len()
        );
        if stream.write_all(head.as_bytes()).is_ok() {
            let _ = stream.write_all(&resp.body[..resp.body.len() / 2]);
            let _ = stream.flush();
        }
        return;
    }

    let bps = egress.load(Ordering::SeqCst);
    if bps == 0 {
        let _ = write_response(&mut stream, &resp);
        return;
    }
    // Bandwidth shaping: stream the body in 64 KiB chunks, sleeping to hold
    // the configured rate (WAN emulation for §4.2 broadcast timing).
    let mut head = format!(
        "HTTP/1.1 {} {}\r\ncontent-length: {}\r\nconnection: close\r\n",
        resp.status,
        Response::status_text(resp.status),
        resp.body.len()
    );
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    if stream.write_all(head.as_bytes()).is_err() {
        return;
    }
    let chunk = 64 * 1024usize;
    let start = Instant::now();
    let mut sent = 0usize;
    for part in resp.body.chunks(chunk) {
        if stream.write_all(part).is_err() {
            return;
        }
        sent += part.len();
        let target = sent as f64 / bps as f64;
        let actual = start.elapsed().as_secs_f64();
        if target > actual {
            std::thread::sleep(Duration::from_secs_f64(target - actual));
        }
    }
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::HttpClient;
    use crate::util::json::Json;

    fn echo_server(cfg: ServerConfig) -> HttpServer {
        HttpServer::start(cfg, |req| {
            Response::json(&Json::obj(vec![
                ("path", req.path.as_str().into()),
                ("body_len", req.body.len().into()),
                ("q", req.query.get("q").cloned().unwrap_or_default().into()),
            ]))
        })
        .unwrap()
    }

    #[test]
    fn request_response_roundtrip() {
        let srv = echo_server(ServerConfig::default());
        let client = HttpClient::new("tester");
        let resp = client.post(&format!("{}/x/y?q=hi%20there", srv.url()), b"abc".to_vec()).unwrap();
        assert_eq!(resp.status, 200);
        let v = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("path").unwrap().as_str().unwrap(), "/x/y");
        assert_eq!(v.get("body_len").unwrap().as_u64().unwrap(), 3);
        assert_eq!(v.get("q").unwrap().as_str().unwrap(), "hi there");
    }

    #[test]
    fn rate_limit_trips() {
        let cfg = ServerConfig { rate_limit_rps: 5.0, rate_limit_burst: 3.0, ..Default::default() };
        let srv = echo_server(cfg);
        let client = HttpClient::new("flooder");
        let mut limited = 0;
        for _ in 0..10 {
            let r = client.get(&format!("{}/", srv.url())).unwrap();
            if r.status == 429 {
                limited += 1;
            }
        }
        assert!(limited >= 4, "{limited}");
        assert!(srv.stats.rejected_rate.get() >= 4);
    }

    #[test]
    fn firewall_blocks_unknown_nodes() {
        let cfg = ServerConfig { firewall_enabled: true, ..Default::default() };
        let srv = echo_server(cfg);
        srv.set_allowlist(vec!["good-node".into()]);
        let bad = HttpClient::new("evil-node");
        assert_eq!(bad.get(&format!("{}/", srv.url())).unwrap().status, 403);
        let good = HttpClient::new("good-node");
        assert_eq!(good.get(&format!("{}/", srv.url())).unwrap().status, 200);
        assert_eq!(srv.stats.rejected_firewall.get(), 1);
    }

    #[test]
    fn server_faults_fire_and_replay_deterministically() {
        use crate::http::faults::{FaultInjector, FaultSpec};
        // Mixed spec over every class; hang kept short so the test is fast.
        let spec = FaultSpec {
            fault_rate: 0.6,
            burst_len: 2,
            hang_ms: 50,
            max_delay_ms: 5,
            ..Default::default()
        };
        let outcomes = |seed: u64| -> Vec<String> {
            let cfg = ServerConfig {
                faults: Some(FaultInjector::from_seed(seed, spec.clone())),
                ..Default::default()
            };
            let body = vec![9u8; 32 * 1024];
            let srv = HttpServer::start(cfg, move |_| Response::ok(body.clone())).unwrap();
            let mut client = HttpClient::new("chaos");
            client.timeout = Duration::from_millis(500);
            (0..24)
                .map(|_| match client.get(&srv.url()) {
                    Ok(r) => format!("status {}", r.status),
                    Err(_) => "error".to_string(),
                })
                .collect()
        };
        let a = outcomes(42);
        let b = outcomes(42);
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        // The mix actually exercised both failure and success paths.
        assert!(a.iter().any(|o| o == "status 200"), "{a:?}");
        assert!(a.iter().any(|o| o != "status 200"), "{a:?}");
    }

    #[test]
    fn truncated_response_is_a_client_error_not_a_prefix() {
        use crate::http::faults::{FaultInjector, FaultSpec};
        let spec = FaultSpec {
            fault_rate: 1.0,
            burst_len: 1,
            w_refuse: 0.0,
            w_hang: 0.0,
            w_5xx: 0.0,
            w_truncate: 1.0,
            w_delay: 0.0,
            ..Default::default()
        };
        let faults = Some(FaultInjector::from_seed(5, spec));
        let cfg = ServerConfig { faults, ..Default::default() };
        let srv = HttpServer::start(cfg, |_| Response::ok(vec![1u8; 64 * 1024])).unwrap();
        let mut client = HttpClient::new("t");
        client.timeout = Duration::from_millis(500);
        assert!(client.get(&srv.url()).is_err(), "short body must not parse as success");
    }

    #[test]
    fn partition_severs_one_direction_then_heals() {
        use crate::http::Partition;
        let partition = Partition::new();
        let cfg = ServerConfig {
            partition: Some(Arc::clone(&partition)),
            domain: "origin".into(),
            ..Default::default()
        };
        let srv = echo_server(cfg);
        let mut cut_off = HttpClient::new("relay-tree-r1");
        cut_off.timeout = Duration::from_millis(400);
        let bystander = HttpClient::new("relay-tree-r2");
        partition.advance_to(1);
        partition.cut("relay-tree-r1", "origin", 1);
        assert!(cut_off.get(&srv.url()).is_err(), "severed link must refuse");
        assert_eq!(bystander.get(&srv.url()).unwrap().status, 200, "cut is pairwise");
        assert!(partition.refused.get() >= 1);
        partition.advance_to(2);
        assert_eq!(cut_off.get(&srv.url()).unwrap().status, 200, "cut heals after N steps");
    }

    #[test]
    fn start_on_rebinds_a_fixed_address() {
        // Reserve a port by bind-then-drop, then serve on it explicitly —
        // the restart scenario: clients keep a fixed URL across a bounce.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let srv =
            HttpServer::start_on(&addr, ServerConfig::default(), |_| Response::ok("up")).unwrap();
        assert_eq!(srv.addr, addr);
        let c = HttpClient::new("t");
        assert_eq!(c.get(&srv.url()).unwrap().body, b"up");
    }

    #[test]
    fn bandwidth_shaping_slows_transfer() {
        let body = vec![7u8; 512 * 1024];
        let cfg = ServerConfig { egress_bytes_per_sec: 2 * 1024 * 1024, ..Default::default() };
        let srv = HttpServer::start(cfg, move |_| Response::ok(body.clone())).unwrap();
        let client = HttpClient::new("dl");
        let t0 = Instant::now();
        let r = client.get(&format!("{}/blob", srv.url())).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(r.body.len(), 512 * 1024);
        // 512 KiB at 2 MiB/s ≈ 0.25 s.
        assert!(dt > 0.15, "transfer too fast: {dt}");
    }
}
