//! Blocking HTTP/1.1 client. Every request carries an `x-node-id` header —
//! the identity the relay firewall / rate limiter keys on (loopback peers
//! all share 127.0.0.1, so the node id plays the role of the source IP).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

use super::faults::{Fault, FaultInjector};
use super::Response;

#[derive(Clone)]
pub struct HttpClient {
    pub node_id: String,
    pub timeout: Duration,
    /// Simulated ingress bandwidth in bytes/sec (0 = unshaped); models a
    /// heterogeneous worker's downlink (§4.2).
    pub ingress_bytes_per_sec: u64,
    /// Optional client-side fault plane: models an unreliable egress link.
    /// Only [`Fault::Refuse`] (request fails before the wire) and
    /// [`Fault::Delay`] apply here — the other classes are server
    /// behaviors.
    pub faults: Option<Arc<FaultInjector>>,
}

impl HttpClient {
    pub fn new(node_id: &str) -> HttpClient {
        HttpClient {
            node_id: node_id.to_string(),
            timeout: Duration::from_secs(30),
            ingress_bytes_per_sec: 0,
            faults: None,
        }
    }

    pub fn with_ingress(mut self, bps: u64) -> HttpClient {
        self.ingress_bytes_per_sec = bps;
        self
    }

    pub fn with_faults(mut self, faults: Arc<FaultInjector>) -> HttpClient {
        self.faults = Some(faults);
        self
    }

    pub fn get(&self, url: &str) -> anyhow::Result<Response> {
        self.request("GET", url, Vec::new())
    }

    pub fn post(&self, url: &str, body: Vec<u8>) -> anyhow::Result<Response> {
        self.request("POST", url, body)
    }

    pub fn post_json(&self, url: &str, v: &crate::util::json::Json) -> anyhow::Result<Response> {
        self.request("POST", url, v.to_string().into_bytes())
    }

    pub fn request(&self, method: &str, url: &str, body: Vec<u8>) -> anyhow::Result<Response> {
        match self.faults.as_ref().and_then(|f| f.next_fault()) {
            Some(Fault::Refuse) => anyhow::bail!("fault injection: connection refused ({url})"),
            Some(Fault::Delay { ms }) => std::thread::sleep(Duration::from_millis(ms)),
            _ => {}
        }
        let rest = url.strip_prefix("http://").ok_or_else(|| anyhow::anyhow!("bad url: {url}"))?;
        let (host, path) = match rest.split_once('/') {
            Some((h, p)) => (h, format!("/{p}")),
            None => (rest, "/".to_string()),
        };
        // Resolve ourselves so the connect honors `self.timeout` — a bare
        // `TcpStream::connect` waits out the OS default (minutes against a
        // black-holing peer), which stalls every retry loop above us.
        let mut stream = None;
        let mut last: Option<std::io::Error> = None;
        for addr in host.to_socket_addrs()? {
            match TcpStream::connect_timeout(&addr, self.timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last = Some(e),
            }
        }
        let mut stream = match stream {
            Some(s) => s,
            None => match last {
                Some(e) => return Err(anyhow::anyhow!("connect {host}: {e}")),
                None => anyhow::bail!("connect {host}: no addresses resolved"),
            },
        };
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;

        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: {host}\r\nx-node-id: {}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
            self.node_id,
            body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&body)?;
        stream.flush()?;

        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad status line: {status_line:?}"))?;

        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                let k = k.trim().to_ascii_lowercase();
                let v = v.trim().to_string();
                if k == "content-length" {
                    content_length = v.parse().unwrap_or(0);
                }
                headers.push((k, v));
            }
        }

        let body = if self.ingress_bytes_per_sec == 0 {
            let mut b = vec![0u8; content_length];
            reader.read_exact(&mut b)?;
            b
        } else {
            // Shaped read: consume in chunks, pacing to the downlink rate.
            let mut b = Vec::with_capacity(content_length);
            let start = std::time::Instant::now();
            let mut chunk = vec![0u8; 64 * 1024];
            while b.len() < content_length {
                let want = chunk.len().min(content_length - b.len());
                reader.read_exact(&mut chunk[..want])?;
                b.extend_from_slice(&chunk[..want]);
                let target = b.len() as f64 / self.ingress_bytes_per_sec as f64;
                let actual = start.elapsed().as_secs_f64();
                if target > actual {
                    std::thread::sleep(Duration::from_secs_f64(target - actual));
                }
            }
            b
        };
        Ok(Response { status, headers, body })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::{HttpServer, ServerConfig};

    #[test]
    fn client_ingress_shaping() {
        let body = vec![1u8; 256 * 1024];
        let srv = HttpServer::start(ServerConfig::default(), move |_| super::Response::ok(body.clone())).unwrap();
        let fast = HttpClient::new("fast");
        let slow = HttpClient::new("slow").with_ingress(1024 * 1024);
        let t0 = std::time::Instant::now();
        fast.get(&srv.url()).unwrap();
        let t_fast = t0.elapsed();
        let t0 = std::time::Instant::now();
        slow.get(&srv.url()).unwrap();
        let t_slow = t0.elapsed();
        assert!(t_slow > t_fast, "{t_slow:?} vs {t_fast:?}");
        assert!(t_slow.as_secs_f64() > 0.15);
    }

    #[test]
    fn connect_honors_timeout_against_non_accepting_socket() {
        // 10.255.255.1 is an RFC-1918 black hole on CI runners: SYNs are
        // dropped (or administratively refused), never answered. With the
        // old bare `TcpStream::connect` this hung for the OS default
        // (minutes); with `connect_timeout` it must fail within our budget.
        let mut c = HttpClient::new("t");
        c.timeout = std::time::Duration::from_millis(300);
        let t0 = std::time::Instant::now();
        let r = c.get("http://10.255.255.1:9/x");
        let dt = t0.elapsed();
        assert!(r.is_err());
        assert!(dt < std::time::Duration::from_secs(5), "connect took {dt:?}");
    }

    #[test]
    fn refused_port_errors_fast() {
        // Bind-then-drop guarantees an unused loopback port: connecting
        // gets an immediate RST, not a timeout.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let c = HttpClient::new("t");
        let t0 = std::time::Instant::now();
        assert!(c.get(&format!("http://{addr}/")).is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn client_side_fault_injection_refuses_deterministically() {
        use crate::http::faults::{FaultInjector, FaultSpec};
        let srv =
            HttpServer::start(ServerConfig::default(), |_| super::Response::ok("hi")).unwrap();
        let spec = FaultSpec {
            fault_rate: 1.0,
            burst_len: 1,
            w_refuse: 1.0,
            w_hang: 0.0,
            w_5xx: 0.0,
            w_truncate: 0.0,
            w_delay: 0.0,
            ..Default::default()
        };
        let run = |seed: u64| -> Vec<bool> {
            let c = HttpClient::new("t").with_faults(FaultInjector::from_seed(seed, spec.clone()));
            (0..20).map(|_| c.get(&srv.url()).is_ok()).collect()
        };
        // All-refuse spec: every request dies before the wire.
        assert!(run(1).iter().all(|ok| !ok));
        // Partial rate replays identically across runs with the same seed.
        let spec2 = FaultSpec { fault_rate: 0.5, ..spec };
        let partial = |seed: u64| -> Vec<bool> {
            let c = HttpClient::new("t").with_faults(FaultInjector::from_seed(seed, spec2.clone()));
            (0..30).map(|_| c.get(&srv.url()).is_ok()).collect()
        };
        assert_eq!(partial(7), partial(7));
        assert!(partial(7).iter().any(|ok| *ok));
        assert!(partial(7).iter().any(|ok| !ok));
    }

    #[test]
    fn error_status_propagates() {
        let srv = HttpServer::start(ServerConfig::default(), |_| super::Response::error(404, "nope")).unwrap();
        let c = HttpClient::new("x");
        let r = c.get(&format!("{}/missing", srv.url())).unwrap();
        assert_eq!(r.status, 404);
        assert_eq!(r.body, b"nope");
    }
}
