//! From-scratch HTTP/1.1 (the offline crate set has no hyper/tokio).
//!
//! Serves three roles in the reproduction:
//! - SHARDCAST relay servers (§2.2) — shard uploads/downloads with
//!   bandwidth shaping, per-IP rate limiting and an allowlist firewall;
//! - the orchestrator / discovery-service APIs (§2.4);
//! - the PRIME-RL step-counter endpoint inference workers poll (§2.1.2).
//!
//! Both halves carry optional hooks for the deterministic fault plane
//! ([`faults`]): a seeded [`FaultInjector`] can refuse, hang, 5xx,
//! truncate or delay requests on either side, replaying byte-identically
//! from its seed — the chaos substrate the churn e2e and `churn_bench`
//! drive.

pub mod client;
pub mod faults;
pub mod server;

pub use client::HttpClient;
pub use faults::{Fault, FaultInjector, FaultPlan, FaultSpec, Partition};
pub use server::{HttpServer, ServerConfig};

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// Peer address as seen by the server (firewall / rate-limit key).
    pub peer: String,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    pub fn query_u64(&self, key: &str, default: u64) -> u64 {
        self.query.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn json(&self) -> anyhow::Result<crate::util::json::Json> {
        Ok(crate::util::json::Json::parse(std::str::from_utf8(&self.body)?)?)
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Vec::new() }
    }

    pub fn ok(body: impl Into<Vec<u8>>) -> Response {
        Response { status: 200, headers: Vec::new(), body: body.into() }
    }

    pub fn json(v: &crate::util::json::Json) -> Response {
        let mut r = Response::ok(v.to_string().into_bytes());
        r.headers.push(("content-type".into(), "application/json".into()));
        r
    }

    pub fn error(status: u16, msg: &str) -> Response {
        Response { status, headers: Vec::new(), body: msg.as_bytes().to_vec() }
    }

    pub fn with_header(mut self, k: &str, v: &str) -> Response {
        self.headers.push((k.to_string(), v.to_string()));
        self
    }

    pub fn status_text(status: u16) -> &'static str {
        match status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            409 => "Conflict",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

pub(crate) fn parse_request(stream: &mut TcpStream, max_body: usize) -> anyhow::Result<Request> {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.trim_end().split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("/").to_string();
    if method.is_empty() {
        anyhow::bail!("empty request line");
    }

    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut query = BTreeMap::new();
    for pair in query_str.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.insert(urldecode(k), urldecode(v));
    }

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let len: usize = headers.get("content-length").and_then(|v| v.parse().ok()).unwrap_or(0);
    if len > max_body {
        anyhow::bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, query, headers, body, peer })
}

pub(crate) fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\ncontent-length: {}\r\nconnection: close\r\n",
        resp.status,
        Response::status_text(resp.status),
        resp.body.len()
    );
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

pub fn urlencode(s: &str) -> String {
    let mut out = String::new();
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' | b'/' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

pub fn urldecode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'%' && i + 2 < b.len() + 1 && i + 2 < b.len() {
            if let Ok(v) = u8::from_str_radix(&s[i + 1..i + 3], 16) {
                out.push(v);
                i += 3;
                continue;
            }
        }
        if b[i] == b'+' {
            out.push(b' ');
        } else {
            out.push(b[i]);
        }
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_roundtrip() {
        let s = "a b/c?d=1&e=ü";
        let enc = urlencode(s);
        assert!(!enc.contains(' '));
        assert_eq!(urldecode(&enc), s);
    }

    #[test]
    fn status_text_known() {
        assert_eq!(Response::status_text(429), "Too Many Requests");
    }
}
