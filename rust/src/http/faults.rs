//! Deterministic, seeded fault injection for the HTTP substrate.
//!
//! The paper trains over "a dynamic, heterogeneous swarm of permissionless
//! compute contributors" (§2.4) — relays restart, workers vanish mid-task,
//! WAN links black-hole. This module is the chaos plane that makes those
//! failures testable: a [`FaultPlan`] maps every request index to an
//! optional [`Fault`], as a *pure function* of `(seed, index)` driven by
//! [`crate::util::rng::Rng`]. Two injectors built from the same seed and
//! spec produce byte-identical fault schedules, no matter how requests are
//! interleaved across threads — index `i` always gets the same fault —
//! so every chaos run replays exactly.
//!
//! Scheduling is per *window* of `burst_len` consecutive requests: one RNG
//! draw (from `Rng::new(seed).fold(window)`) decides the whole window, so
//! 5xx storms and refusal outages arrive in realistic contiguous bursts
//! rather than i.i.d. sprinkles.
//!
//! Process-level churn (crashing a relay or worker outright) cannot be
//! injected at the request layer; harnesses drive it from the same plan
//! via [`FaultPlan::pick`], which deterministically selects the victim for
//! a given step.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::metrics::Counter;
use crate::util::rng::Rng;

/// One injected failure, applied to a single HTTP request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Drop the connection without reading the request (the TCP-level
    /// behavior of a crashed or refusing peer).
    Refuse,
    /// Read the request, then hold the connection open for `ms` without
    /// responding, then drop it (a hung peer; exercises client timeouts).
    Hang { ms: u64 },
    /// Respond with this 5xx status instead of invoking the handler.
    Status(u16),
    /// Serve the real response head (full `content-length`) but only the
    /// first half of the body, then drop the connection (mid-body
    /// truncation; the client sees a short read).
    Truncate,
    /// Sleep `ms`, then handle normally (added latency).
    Delay { ms: u64 },
}

/// Fault mix for a plan: how often a window is faulty and the relative
/// weight of each fault class when it is.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Probability that a burst window is faulty at all.
    pub fault_rate: f64,
    /// Number of consecutive requests covered by one scheduling decision.
    pub burst_len: u64,
    pub w_refuse: f64,
    pub w_hang: f64,
    pub w_5xx: f64,
    pub w_truncate: f64,
    pub w_delay: f64,
    /// How long a [`Fault::Hang`] holds the connection.
    pub hang_ms: u64,
    /// Upper bound on [`Fault::Delay`] latency.
    pub max_delay_ms: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            fault_rate: 0.2,
            burst_len: 3,
            w_refuse: 1.0,
            w_hang: 0.5,
            w_5xx: 2.0,
            w_truncate: 1.0,
            w_delay: 2.0,
            hang_ms: 300,
            max_delay_ms: 50,
        }
    }
}

/// A deterministic fault schedule: `fault_at(idx)` is a pure function of
/// `(seed, spec, idx)`.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
}

impl FaultPlan {
    pub fn new(seed: u64, spec: FaultSpec) -> FaultPlan {
        FaultPlan { seed, spec }
    }

    /// The fault (if any) scheduled for the `idx`-th request an injector
    /// sees. Requests in the same `burst_len` window share one decision.
    pub fn fault_at(&self, idx: u64) -> Option<Fault> {
        let window = idx / self.spec.burst_len.max(1);
        let mut rng = Rng::new(self.seed).fold(window);
        if !rng.bool(self.spec.fault_rate) {
            return None;
        }
        let s = &self.spec;
        let weights = [s.w_refuse, s.w_hang, s.w_5xx, s.w_truncate, s.w_delay];
        Some(match rng.weighted(&weights) {
            0 => Fault::Refuse,
            1 => Fault::Hang { ms: s.hang_ms },
            2 => Fault::Status(if rng.bool(0.5) { 500 } else { 503 }),
            3 => Fault::Truncate,
            _ => Fault::Delay { ms: 1 + rng.range(0, s.max_delay_ms.max(1)) },
        })
    }

    /// Deterministic victim selection for process-level churn: which of
    /// `n` candidates crashes at `step` in the given `domain` (a caller-
    /// chosen stream id separating e.g. worker-crash picks from
    /// relay-kill picks). Pure in `(seed, domain, step, n)`.
    pub fn pick(&self, domain: u64, step: u64, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let mut rng = Rng::new(self.seed).fold(domain).fold(step.wrapping_add(0x51E9));
        rng.usize(n)
    }
}

/// One directional netsplit: requests whose source domain matches `src`
/// arriving at a server whose domain matches `dst` are refused while the
/// cut is live. Domains match by prefix (`""`/`"*"` match anything), so a
/// harness can sever one relay (`"relay-tree-r1"`), a whole tier
/// (`"relay-"`), or everything (`"*"`).
#[derive(Clone, Debug)]
struct Cut {
    src: String,
    dst: String,
    /// First step index at which the cut no longer applies.
    until_step: u64,
}

/// Netsplit fault plane: a set of (src-domain, dst-domain) pairs that
/// Refuse for N steps. Unlike [`FaultPlan`] (seeded, per-request), cuts
/// are placed explicitly by the harness at known step indices and heal
/// themselves when the shared step counter passes `until_step` — a
/// partition is scheduled topology damage, not random noise, so replays
/// are trivially deterministic.
///
/// Servers consult [`Partition::severed`] after reading the request (the
/// source identity rides the `x-node-id` header), then drop the socket —
/// from the client side a severed link looks exactly like
/// [`Fault::Refuse`].
#[derive(Default)]
pub struct Partition {
    cuts: Mutex<Vec<Cut>>,
    step: AtomicU64,
    /// Requests dropped by a live cut.
    pub refused: Counter,
}

impl Partition {
    pub fn new() -> Arc<Partition> {
        Arc::new(Partition::default())
    }

    /// Sever `src -> dst` for the next `steps` steps (from the current
    /// step counter). Directional: cut both ways for a full netsplit.
    pub fn cut(&self, src: &str, dst: &str, steps: u64) {
        let until_step = self.step.load(Ordering::SeqCst).saturating_add(steps);
        self.cuts.lock().unwrap().push(Cut {
            src: src.to_string(),
            dst: dst.to_string(),
            until_step,
        });
    }

    /// Advance the shared step counter (harness-driven, once per churn
    /// step); expired cuts heal and are dropped.
    pub fn advance_to(&self, step: u64) {
        self.step.store(step, Ordering::SeqCst);
        self.cuts.lock().unwrap().retain(|c| c.until_step > step);
    }

    fn domain_matches(pat: &str, domain: &str) -> bool {
        pat.is_empty() || pat == "*" || domain.starts_with(pat)
    }

    /// Is the `src -> dst` link severed right now?
    pub fn severed(&self, src: &str, dst: &str) -> bool {
        let step = self.step.load(Ordering::SeqCst);
        self.cuts.lock().unwrap().iter().any(|c| {
            c.until_step > step
                && Partition::domain_matches(&c.src, src)
                && Partition::domain_matches(&c.dst, dst)
        })
    }

    /// Cuts currently live (for harness reporting).
    pub fn live_cuts(&self) -> usize {
        let step = self.step.load(Ordering::SeqCst);
        self.cuts.lock().unwrap().iter().filter(|c| c.until_step > step).count()
    }
}

/// Per-injector fault accounting (what actually fired, by class).
#[derive(Default)]
pub struct FaultStats {
    pub injected: Counter,
    pub refused: Counter,
    pub hung: Counter,
    pub served_5xx: Counter,
    pub truncated: Counter,
    pub delayed: Counter,
}

/// Threads a [`FaultPlan`] through a server or client: each request takes
/// the next index off an atomic counter and looks up its scheduled fault.
pub struct FaultInjector {
    plan: FaultPlan,
    next_idx: AtomicU64,
    pub stats: FaultStats,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Arc<FaultInjector> {
        Arc::new(FaultInjector { plan, next_idx: AtomicU64::new(0), stats: FaultStats::default() })
    }

    pub fn from_seed(seed: u64, spec: FaultSpec) -> Arc<FaultInjector> {
        FaultInjector::new(FaultPlan::new(seed, spec))
    }

    /// The fault for the next request, advancing the request index. The
    /// index assignment depends on arrival order, but the *schedule* does
    /// not: index `i` maps to the same fault on every run.
    pub fn next_fault(&self) -> Option<Fault> {
        let idx = self.next_idx.fetch_add(1, Ordering::SeqCst);
        let f = self.plan.fault_at(idx);
        if let Some(fault) = f {
            self.stats.injected.inc();
            match fault {
                Fault::Refuse => self.stats.refused.inc(),
                Fault::Hang { .. } => self.stats.hung.inc(),
                Fault::Status(_) => self.stats.served_5xx.inc(),
                Fault::Truncate => self.stats.truncated.inc(),
                Fault::Delay { .. } => self.stats.delayed.inc(),
            };
        }
        f
    }

    /// Requests seen so far (assigned indices).
    pub fn requests_seen(&self) -> u64 {
        self.next_idx.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn identical_seeds_replay_identical_schedules() {
        // Property: for arbitrary (seed, spec knobs), two independently
        // constructed plans agree on every index — the replayability
        // contract the whole chaos layer rests on.
        prop::check(
            "fault_plan_replay",
            50,
            |rng, _size| (rng.next_u64(), rng.f64(), 1 + rng.range(0, 8)),
            |&(seed, rate, burst)| {
                let spec = FaultSpec { fault_rate: rate, burst_len: burst, ..Default::default() };
                let a = FaultPlan::new(seed, spec.clone());
                let b = FaultPlan::new(seed, spec);
                for idx in 0..2_000u64 {
                    prop::ensure(
                        a.fault_at(idx) == b.fault_at(idx),
                        &format!("schedules diverge at idx {idx}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn different_seeds_differ() {
        let spec = FaultSpec { fault_rate: 0.5, ..Default::default() };
        let a = FaultPlan::new(1, spec.clone());
        let b = FaultPlan::new(2, spec);
        let diverged = (0..500).any(|i| a.fault_at(i) != b.fault_at(i));
        assert!(diverged);
    }

    #[test]
    fn faults_arrive_in_aligned_bursts() {
        let spec = FaultSpec { fault_rate: 0.5, burst_len: 4, ..Default::default() };
        let plan = FaultPlan::new(9, spec);
        for window in 0..200u64 {
            let first = plan.fault_at(window * 4);
            for off in 1..4 {
                assert_eq!(plan.fault_at(window * 4 + off), first, "window {window} not uniform");
            }
        }
    }

    #[test]
    fn rate_extremes() {
        let none = FaultPlan::new(3, FaultSpec { fault_rate: 0.0, ..Default::default() });
        assert!((0..500).all(|i| none.fault_at(i).is_none()));
        let all = FaultPlan::new(3, FaultSpec { fault_rate: 1.0, ..Default::default() });
        assert!((0..500).all(|i| all.fault_at(i).is_some()));
    }

    #[test]
    fn injector_counts_by_class() {
        let spec = FaultSpec { fault_rate: 1.0, burst_len: 1, ..Default::default() };
        let inj = FaultInjector::from_seed(11, spec);
        for _ in 0..100 {
            let _ = inj.next_fault();
        }
        assert_eq!(inj.requests_seen(), 100);
        assert_eq!(inj.stats.injected.get(), 100);
        let by_class = inj.stats.refused.get()
            + inj.stats.hung.get()
            + inj.stats.served_5xx.get()
            + inj.stats.truncated.get()
            + inj.stats.delayed.get();
        assert_eq!(by_class, 100);
    }

    #[test]
    fn partition_cuts_match_by_prefix_and_heal_by_step() {
        let p = Partition::new();
        p.advance_to(5);
        p.cut("relay-tree-r1", "origin", 2); // live for steps 5, 6
        p.cut("worker-", "relay-", 1); // tier-wide, one step
        assert!(p.severed("relay-tree-r1", "origin"));
        assert!(p.severed("relay-tree-r1-puller", "origin"), "prefix must match");
        assert!(!p.severed("relay-tree-r2", "origin"));
        assert!(!p.severed("origin", "relay-tree-r1"), "cuts are directional");
        assert!(p.severed("worker-42", "relay-tree-r2"));
        assert_eq!(p.live_cuts(), 2);
        p.advance_to(6);
        assert!(!p.severed("worker-42", "relay-tree-r2"), "one-step cut healed");
        assert!(p.severed("relay-tree-r1", "origin"));
        p.advance_to(7);
        assert_eq!(p.live_cuts(), 0);
        assert!(!p.severed("relay-tree-r1", "origin"));
        // Wildcards sever everything.
        p.cut("*", "*", 3);
        assert!(p.severed("anyone", "anywhere"));
    }

    #[test]
    fn pick_is_deterministic_and_in_range() {
        let plan = FaultPlan::new(77, FaultSpec::default());
        for step in 0..100u64 {
            let a = plan.pick(1, step, 5);
            let b = plan.pick(1, step, 5);
            assert_eq!(a, b);
            assert!(a < 5);
            // Different domains make independent choices somewhere.
        }
        let differs = (0..100).any(|s| plan.pick(1, s, 5) != plan.pick(2, s, 5));
        assert!(differs);
    }
}
