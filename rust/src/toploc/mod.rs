//! TOPLOC (paper §2.3): trustless inference verification via
//! locality-sensitive commitments over final hidden states, plus sampling
//! and sanity checks. Validators audit submissions far faster than
//! generation (one prefill vs T decode steps — `benches/toploc_bench.rs`).
//!
//! # The six validation stages
//!
//! Every rollout submission passes through six stages; the first four are
//! pure CPU work, the last two need model prefill:
//!
//! 0. **Signature check** (`coordinator::validation::check_envelope`,
//!    §2.4.1) — the upload's signed envelope is verified against the
//!    ledger's address→key registry before anything else runs: HMAC
//!    signature over the canonical header (node, step, submission index,
//!    payload digest), then the digest against the payload bytes. A valid
//!    envelope *proves* the sender, so every later failure — including a
//!    malformed payload — slashes the signer; a missing or unprovable one
//!    is rejected unslashed (`unsigned` / `forged` counters). Binding the
//!    step into the signature makes replayed envelopes age out with the
//!    staleness window. Governed by `require-signed-submissions` (on for
//!    the real swarm; off restores legacy trust-the-claimed-address
//!    behavior for old fixtures).
//! 1. **File check** ([`Validator::check_file`]) — rpq decode + schema
//!    (the paper's "parquet formatting check"). Malformed files are
//!    rejected — attributed to the proven signer when signing is on,
//!    best-effort otherwise.
//! 2. **Sanity checks** ([`Validator::check_sanity`], §2.3.3) — staleness
//!    window, fixed data-sampling seed, deterministic group ids, value
//!    bounds, and reward re-verification against the environment.
//! 3. **Termination check** ([`Validator::check_termination`], §2.3.2) —
//!    plausible EOS probability or genuine max-length. Failures are
//!    *soft*: the offending group is discarded, the node is not slashed.
//! 4. **Computation check** ([`Validator::check_computation`], §2.3.1) —
//!    the TOPLOC commitment's top-k hidden-state coordinates must match a
//!    prefill recomputation within index-overlap and value tolerances.
//! 5. **Sampling checks** ([`Validator::check_sampling`], §2.3.2) —
//!    calibrated bimodality test on recomputed token probabilities
//!    (catches decode-with-a-smaller-model) and median agreement with the
//!    reported per-token probs (catches fabricated reports).
//!
//! # Pipeline topology
//!
//! The validator node (`coordinator::validation::ValidationPipeline`)
//! runs these stages as a two-stage pipeline over *waves* of submissions
//! pulled from a bounded FIFO ingest queue:
//!
//! - **CPU stage** — stages 0–3 fan out across a `util::pool::ThreadPool`
//!   (`validator-threads` knob), one job per submission.
//! - **Prefill stage** — survivors are grouped by claimed policy version,
//!   then [`pipeline::plan_prefills`] packs their rollouts — across
//!   submissions — into length-bucketed prefill calls: lanes sorted
//!   longest-first, `batch_infer` per call, each call padded only to its
//!   longest lane rounded up to the bucket grain (`prefill-bucket-tokens`
//!   knob; 0 = the model's TOPLOC commit interval). Stages 4–5 run on
//!   each lane and verdicts are attributed back per submission.
//!
//! The old path — one thread, one submission at a time, every prefill
//! padded to the full `batch_infer x max_seq` frame — survives as
//! `coordinator::validation::validate_submission_fullpad`, the reference
//! baseline the equivalence tests and `toploc_bench` compare against.
//! The runtime picks the cheapest compiled `prefill_{T}` artifact
//! covering each call (`ModelSpec::prefill_artifact_for`), falling back
//! to the full frame when only `prefill` is shipped — packing still wins
//! there by filling all lanes and issuing fewer calls.
//!
//! # Sampled verification: the trust-weighted pre-stage
//!
//! With `sampling-rate < 1.0` a pre-stage
//! (`coordinator::validation::SamplingGate`) runs *before* the pipeline
//! above and decides, per submission, whether the *expensive* checks run
//! at all. Only the env reward replay (stage 2's costly half) and the
//! engine stages (4–5) are ever sampled away. Everything deterministic
//! and cheap always runs, skip or no skip: stage 0 (envelope), stage 1
//! (decode/schema), the identity cross-check, and the deterministic
//! subset of stages 2–3 ([`Validator::check_sanity_pre`]: staleness,
//! seed/rollout-count, group ids, value/reward bounds, the
//! per-submission rollout cap, plus the overlong and termination
//! screens). Only then are a skipped submission's *claimed* rewards
//! admitted to the rollout buffer (counted
//! `rollouts_admitted_unverified` and flagged "(unverified)" in the
//! per-env pass table). The cap matters economically: the task stream is
//! prefix-stable, so without it a skipped upload could claim unboundedly
//! many seed-consistent rollouts against a fixed stake.
//!
//! **Trust model** (`protocol::TrustState`): a node's verification
//! probability starts at 1.0 and stays there until it banks
//! `trust-promotion-streak` consecutive fully-verified clean submissions;
//! past promotion it decays as `promotion_streak / clean_streak` down to
//! the `sampling-rate` floor. Any reject zeroes the streak and bumps a
//! lifetime reject count — the node is re-escalated to full verification
//! and must re-earn the entire streak. Skipped submissions deliberately
//! do not move trust: only verification evidence counts, so a node cannot
//! launder trust through uploads that were never checked.
//!
//! **Unpredictable but replayable selection**: which submissions are
//! audited is drawn from a validator secret committed (hash published)
//! before uploads and revealed after
//! (`coordinator::validation::ValidatorCommitment`). The draw is a pure
//! function of `(secret, step, node, submission_idx)`, so any auditor
//! holding the reveal reproduces the exact audit set bit-for-bit (the
//! determinism contract below extends to selection), while a worker
//! without the secret cannot tell which of its uploads will be checked —
//! `tests/sampling_selection.rs` pins both properties.
//!
//! **Why cheating stays negative-EV**: a cheat caught by a spot check
//! forfeits the node's entire stake. With per-submission reward `R`,
//! verification probability `p`, and stake `S`, the cheat's expected
//! value is `(1-p)·R - p·S`, negative iff `S > R·(1-p)/p`. The swarm
//! sizes stakes with `protocol::min_negative_ev_stake` at the *floor*
//! rate (the cheater's best case) times a safety margin
//! (`trust-stake-margin`), so the inequality holds at every trust level
//! and every configured `sampling-rate`. `R` is a real bound, not an
//! assumption: the validator's `max_rollouts_per_sub` cap (set to the
//! per-worker quota) and the value-bounds check are enforced on the skip
//! path too, so no submission can *claim* more reward units than the
//! stake was sized against. The CI `cheat-ev` job
//! (`bin/cheat_ev_bench`, `coordinator::cheatev`) proves it end-to-end:
//! eager, sleeper and deep-sleeper cheaters all finish with negative
//! realized value at rates 1.0/0.25/0.1, no honest node is slashed, and
//! at rate 1.0 the gated verdict stream is byte-identical to the ungated
//! pipeline's.
//!
//! # Generation side: scheduling never reaches the wire
//!
//! The commitments this module audits are produced by the workers'
//! continuous-batching decode scheduler (`runtime::scheduler`, `gen-refill`
//! knob): sequences share `batch_infer` lanes, retire on EOS, and prompts
//! are prefilled straight into the KV cache (one bucketed `prefill_kv_{T}`
//! call per refill wave, GRPO groups sharing one prompt forward). None of
//! that is observable here, by construction: sampling draws from
//! per-rollout RNG streams keyed by `(gen_seed, rollout_index)`
//! (`runtime::scheduler::rollout_rng`), and each rollout's tokens,
//! `sampled_probs` and commit-grid hidden rows are functions of its own
//! prompt and stream only — byte-identical whether the worker ran the
//! continuous engine, the static reference engine, or either under
//! different load. That lane-invariance is what keeps the §2.3.3
//! fixed-sampling check *slashable*: the validator recomputes a rollout
//! without knowing (or caring) how the worker's scheduler packed it.
//! Commit-grid rows for prompt positions come from the prefill forward
//! rather than per-token decode; the two agree exactly up to kernel-shape
//! fp rounding, which the stage-4 tolerances absorb — the same argument
//! the validator's own bucketed `prefill_{T}` ladder already relies on.
//!
//! # The determinism contract
//!
//! Slashing (§2.3.3) is only sound if a verdict is a pure function of
//! the submission bytes and the published policy weights — any other
//! input makes "validator A slashed what validator B accepted" possible,
//! and the swarm's economics collapse to whichever validator you drew.
//! Code on the verdict path therefore obeys four rules, enforced
//! mechanically by `swarmlint` (see [`crate::analysis`]) as a binding CI
//! gate:
//!
//! 1. **No unordered iteration** — `HashMap`/`HashSet` walk order varies
//!    per process (seeded hasher), so anything it feeds — group ids,
//!    serialized bytes, verdict ordering — diverges between validators.
//!    Ordered containers (`BTreeMap`/`BTreeSet`) or explicit sorts only.
//! 2. **No wall-clock or ambient entropy** — `SystemTime`/`Instant`
//!    readings and OS randomness cannot be recomputed by a second
//!    validator. All randomness flows from [`crate::util::rng::Rng`]
//!    seeded constructors; the staleness *policy* input (`current_step`)
//!    enters as an explicit argument, never a clock read.
//! 3. **No panics on untrusted bytes** — a hostile submission must
//!    surface as a reject [`validator::Rejection`] / verdict, never a
//!    panic: a crashing validator is an unslashable denial of service
//!    (and a poisoned one acquits by absence). Parsing goes through
//!    [`crate::util::wire::Cursor`]; float comparisons use `total_cmp`.
//! 4. **Pinned float accumulation** — float addition is non-associative,
//!    so tolerance comparisons are only reproducible if the fold order
//!    is fixed. Accumulations on the verdict path use
//!    [`crate::util::numeric`]'s documented left-to-right folds.
//!
//! The same contract covers the worker-side generation path (tokens,
//! `sampled_probs`, commitments): the worker must be able to reproduce
//! its own bytes under the validator's recomputation, or honest work
//! gets slashed.

pub mod commitment;
pub mod pipeline;
pub mod validator;

pub use commitment::{CommitRow, Commitment};
pub use pipeline::{plan_prefills, LaneReq, PlannedCall};
pub use validator::{Rejection, Validator, ValidatorConfig};
