//! TOPLOC (paper §2.3): trustless inference verification via
//! locality-sensitive commitments over final hidden states, plus sampling
//! and sanity checks. Validators audit submissions far faster than
//! generation (one prefill vs T decode steps — `benches/toploc_bench.rs`).

pub mod commitment;
pub mod validator;

pub use commitment::{Commitment, CommitRow};
pub use validator::{Rejection, Validator, ValidatorConfig};
