//! TOPLOC commitments (§2.3.1): locality-sensitive digests of the final
//! hidden states produced during decoding — captured every 32 tokens plus
//! the final position, as the paper's inference hook does.
//!
//! A commitment row is the top-k coordinates of |hidden| with their values.
//! The validator recomputes hidden states via *prefill* and checks that
//! (a) most top-k indices coincide and (b) the matched values agree within
//! a tolerance — robust to GPU nondeterminism / tensor-parallel layout
//! while reliably detecting different weights or quantized models.

pub const TOPK: usize = 8;
/// Minimum index overlap (out of TOPK) for a row to match.
pub const MIN_OVERLAP: usize = 6;
/// Relative tolerance on matched values.
pub const VALUE_RTOL: f32 = 5e-2;

#[derive(Clone, Debug, PartialEq)]
pub struct CommitRow {
    /// Sequence position this row was captured at.
    pub pos: u32,
    /// Top-k coordinates by |value| (descending).
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

#[derive(Clone, Debug, PartialEq, Default)]
pub struct Commitment {
    pub rows: Vec<CommitRow>,
}

/// Top-k coordinates of |x| (descending by magnitude).
pub fn topk_abs(x: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
    let mut order: Vec<usize> = (0..x.len()).collect();
    order.sort_by(|&a, &b| {
        x[b].abs().partial_cmp(&x[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    let top = &order[..k.min(x.len())];
    (top.iter().map(|&i| i as u32).collect(), top.iter().map(|&i| x[i]).collect())
}

impl Commitment {
    /// Build from captured hidden rows `(pos, hidden[d_model])`.
    pub fn build(hidden_rows: &[(usize, Vec<f32>)], k: usize) -> Commitment {
        Commitment {
            rows: hidden_rows
                .iter()
                .map(|(pos, h)| {
                    let (idx, val) = topk_abs(h, k);
                    CommitRow { pos: *pos as u32, idx, val }
                })
                .collect(),
        }
    }

    /// Serialize: u16 n_rows | per row: u32 pos, u8 k, k*(u32 idx, f32 val).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.rows.len() as u16).to_le_bytes());
        for r in &self.rows {
            out.extend_from_slice(&r.pos.to_le_bytes());
            out.push(r.idx.len() as u8);
            for (&i, &v) in r.idx.iter().zip(&r.val) {
                out.extend_from_slice(&i.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<Commitment> {
        anyhow::ensure!(bytes.len() >= 2, "commitment truncated");
        let n = u16::from_le_bytes(bytes[..2].try_into().unwrap()) as usize;
        let mut pos = 2;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            anyhow::ensure!(pos + 5 <= bytes.len(), "commitment truncated");
            let p = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
            let k = bytes[pos + 4] as usize;
            pos += 5;
            anyhow::ensure!(pos + k * 8 <= bytes.len(), "commitment truncated");
            let mut idx = Vec::with_capacity(k);
            let mut val = Vec::with_capacity(k);
            for _ in 0..k {
                idx.push(u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()));
                val.push(f32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap()));
                pos += 8;
            }
            rows.push(CommitRow { pos: p, idx, val });
        }
        anyhow::ensure!(pos == bytes.len(), "trailing bytes in commitment");
        Ok(Commitment { rows })
    }

    /// Verify against validator-recomputed hidden states (prefill output,
    /// row-major `[T, d_model]`). Returns Err with the first failing row.
    pub fn verify_against(
        &self,
        hidden: &[f32],
        d_model: usize,
        seq_len: usize,
    ) -> Result<(), String> {
        if self.rows.is_empty() {
            return Err("empty commitment".to_string());
        }
        for r in &self.rows {
            let pos = r.pos as usize;
            if pos >= seq_len {
                return Err(format!("commit row at pos {pos} beyond sequence ({seq_len})"));
            }
            let h = &hidden[pos * d_model..(pos + 1) * d_model];
            let (want_idx, _) = topk_abs(h, r.idx.len());
            let overlap = r.idx.iter().filter(|i| want_idx.contains(i)).count();
            let need = MIN_OVERLAP.min(r.idx.len());
            if overlap < need {
                return Err(format!("pos {pos}: top-k overlap {overlap} < {need}"));
            }
            for (&i, &v) in r.idx.iter().zip(&r.val) {
                let actual = h[i as usize];
                let tol = VALUE_RTOL * actual.abs().max(0.05);
                if (actual - v).abs() > tol {
                    return Err(format!(
                        "pos {pos} coord {i}: committed {v} vs recomputed {actual}"
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn hidden_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<(usize, Vec<f32>)> {
        (0..n)
            .map(|i| (i * 32 + 31, (0..d).map(|_| rng.normal() as f32).collect()))
            .collect()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::new(1);
        let rows = hidden_rows(&mut rng, 4, 64);
        let c = Commitment::build(&rows, TOPK);
        let c2 = Commitment::decode(&c.encode()).unwrap();
        assert_eq!(c, c2);
        assert!(Commitment::decode(&c.encode()[..5]).is_err());
    }

    #[test]
    fn verifies_against_matching_hidden() {
        let mut rng = Rng::new(2);
        let d = 64;
        let t = 160;
        let hidden: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let rows: Vec<(usize, Vec<f32>)> = [31usize, 63, 127]
            .iter()
            .map(|&p| (p, hidden[p * d..(p + 1) * d].to_vec()))
            .collect();
        let c = Commitment::build(&rows, TOPK);
        c.verify_against(&hidden, d, t).unwrap();
    }

    #[test]
    fn tolerates_small_numeric_noise() {
        // GPU nondeterminism: small relative perturbations must pass.
        let mut rng = Rng::new(3);
        let d = 64;
        let hidden: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c = Commitment::build(&[(0, hidden.clone())], TOPK);
        let noisy: Vec<f32> = hidden.iter().map(|v| v * 1.005).collect();
        c.verify_against(&noisy, d, 1).unwrap();
    }

    #[test]
    fn detects_different_weights() {
        let mut rng = Rng::new(4);
        let d = 64;
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c = Commitment::build(&[(0, a)], TOPK);
        assert!(c.verify_against(&b, d, 1).is_err());
    }

    #[test]
    fn detects_quantization() {
        // Coarse quantization (int4-ish) shifts values beyond rtol.
        let mut rng = Rng::new(5);
        let d = 64;
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c = Commitment::build(&[(0, a.clone())], TOPK);
        let q: Vec<f32> = a.iter().map(|v| v.round()).collect();
        assert!(c.verify_against(&q, d, 1).is_err());
    }

    #[test]
    fn rejects_out_of_range_positions() {
        let c = Commitment::build(&[(999, vec![1.0; 8])], 4);
        assert!(c.verify_against(&vec![0.0; 64 * 8], 8, 64).is_err());
    }
}
