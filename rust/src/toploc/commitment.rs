//! TOPLOC commitments (§2.3.1): locality-sensitive digests of the final
//! hidden states produced during decoding — captured every 32 tokens plus
//! the final position, as the paper's inference hook does.
//!
//! A commitment row is the top-k coordinates of |hidden| with their values.
//! The validator recomputes hidden states via *prefill* and checks that
//! (a) most top-k indices coincide and (b) the matched values agree within
//! a tolerance — robust to GPU nondeterminism / tensor-parallel layout
//! while reliably detecting different weights or quantized models.

// Trust-critical parse path: untrusted bytes must never panic (swarmlint
// `panic-path`; CI-matched editor feedback via clippy).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::util::wire::Cursor;

pub const TOPK: usize = 8;
/// Minimum index overlap (out of TOPK) for a row to match.
pub const MIN_OVERLAP: usize = 6;
/// Relative tolerance on matched values.
pub const VALUE_RTOL: f32 = 5e-2;

#[derive(Clone, Debug, PartialEq)]
pub struct CommitRow {
    /// Sequence position this row was captured at.
    pub pos: u32,
    /// Top-k coordinates by |value| (descending).
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

#[derive(Clone, Debug, PartialEq, Default)]
pub struct Commitment {
    pub rows: Vec<CommitRow>,
}

/// Top-k coordinates of |x| (descending by magnitude).
///
/// Validator hot path: runs once per commit row on both sides of every
/// computation check, so it partitions the top k out in O(d) with
/// `select_nth_unstable_by` and sorts only those k, instead of fully
/// sorting all `d_model` indices. Ties break by ascending index, which is
/// what the stable full sort this replaces produced — commitments stay
/// bit-identical across the two implementations.
pub fn topk_abs(x: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
    let k = k.min(x.len());
    if k == 0 {
        return (Vec::new(), Vec::new());
    }
    let cmp = |a: &u32, b: &u32| {
        x[*b as usize]
            .abs()
            .partial_cmp(&x[*a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    let mut order: Vec<u32> = (0..x.len() as u32).collect();
    if k < order.len() {
        let _ = order.select_nth_unstable_by(k - 1, cmp);
        order.truncate(k);
    }
    order.sort_unstable_by(cmp);
    let val = order.iter().map(|&i| x[i as usize]).collect();
    (order, val)
}

impl Commitment {
    /// Build from captured hidden rows `(pos, hidden[d_model])`.
    pub fn build(hidden_rows: &[(usize, Vec<f32>)], k: usize) -> Commitment {
        Commitment {
            rows: hidden_rows
                .iter()
                .map(|(pos, h)| {
                    let (idx, val) = topk_abs(h, k);
                    CommitRow { pos: *pos as u32, idx, val }
                })
                .collect(),
        }
    }

    /// Serialize: u16 n_rows | per row: u32 pos, u8 k, k*(u32 idx, f32 val).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.rows.len() as u16).to_le_bytes());
        for r in &self.rows {
            out.extend_from_slice(&r.pos.to_le_bytes());
            out.push(r.idx.len() as u8);
            for (&i, &v) in r.idx.iter().zip(&r.val) {
                out.extend_from_slice(&i.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Decode untrusted commitment bytes. Every read goes through the
    /// panic-free [`Cursor`]: truncation at any boundary is an `Err` (a
    /// reject verdict upstream), never an out-of-bounds panic.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Commitment> {
        fn want<T>(v: Option<T>) -> anyhow::Result<T> {
            v.ok_or_else(|| anyhow::anyhow!("commitment truncated"))
        }
        let mut c = Cursor::new(bytes);
        let n = want(c.u16_le())? as usize;
        let mut rows = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let p = want(c.u32_le())?;
            let k = want(c.u8())? as usize;
            let mut idx = Vec::with_capacity(k);
            let mut val = Vec::with_capacity(k);
            for _ in 0..k {
                idx.push(want(c.u32_le())?);
                val.push(want(c.f32_le())?);
            }
            rows.push(CommitRow { pos: p, idx, val });
        }
        anyhow::ensure!(c.remaining() == 0, "trailing bytes in commitment");
        Ok(Commitment { rows })
    }

    /// Verify against validator-recomputed hidden states (prefill output,
    /// row-major `[T, d_model]`). Returns Err with the first failing row.
    pub fn verify_against(
        &self,
        hidden: &[f32],
        d_model: usize,
        seq_len: usize,
    ) -> Result<(), String> {
        if self.rows.is_empty() {
            return Err("empty commitment".to_string());
        }
        for r in &self.rows {
            let pos = r.pos as usize;
            if pos >= seq_len {
                return Err(format!("commit row at pos {pos} beyond sequence ({seq_len})"));
            }
            // The row contents are attacker-controlled: a short row would
            // lower the overlap bar below MIN_OVERLAP (a single known
            // coordinate would pass), and duplicate or out-of-range
            // coordinates would inflate the overlap count / panic the
            // indexing — all are rejected outright (honest rows are
            // distinct in-range top-k of width >= MIN_OVERLAP whenever
            // d_model allows, so this costs them nothing).
            if r.idx.len() < MIN_OVERLAP.min(d_model) {
                return Err(format!(
                    "pos {pos}: commit row of {} coords (need {})",
                    r.idx.len(),
                    MIN_OVERLAP.min(d_model)
                ));
            }
            let h = &hidden[pos * d_model..(pos + 1) * d_model];
            let (want_idx, _) = topk_abs(h, r.idx.len());
            let mut seen: Vec<u32> = Vec::with_capacity(r.idx.len());
            let mut overlap = 0usize;
            for &i in &r.idx {
                if seen.contains(&i) {
                    return Err(format!("pos {pos}: duplicate committed coordinate {i}"));
                }
                seen.push(i);
                if want_idx.contains(&i) {
                    overlap += 1;
                }
            }
            let need = MIN_OVERLAP.min(r.idx.len());
            if overlap < need {
                return Err(format!("pos {pos}: top-k overlap {overlap} < {need}"));
            }
            for (&i, &v) in r.idx.iter().zip(&r.val) {
                let Some(&actual) = h.get(i as usize) else {
                    return Err(format!(
                        "pos {pos}: committed coordinate {i} outside d_model {d_model}"
                    ));
                };
                // NaN would sail through the tolerance comparison below
                // (NaN > tol is false), neutering the value check.
                if !v.is_finite() {
                    return Err(format!("pos {pos} coord {i}: non-finite committed value"));
                }
                let tol = VALUE_RTOL * actual.abs().max(0.05);
                if (actual - v).abs() > tol {
                    return Err(format!(
                        "pos {pos} coord {i}: committed {v} vs recomputed {actual}"
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn hidden_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<(usize, Vec<f32>)> {
        (0..n)
            .map(|i| (i * 32 + 31, (0..d).map(|_| rng.normal() as f32).collect()))
            .collect()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::new(1);
        let rows = hidden_rows(&mut rng, 4, 64);
        let c = Commitment::build(&rows, TOPK);
        let c2 = Commitment::decode(&c.encode()).unwrap();
        assert_eq!(c, c2);
        assert!(Commitment::decode(&c.encode()[..5]).is_err());
    }

    #[test]
    fn verifies_against_matching_hidden() {
        let mut rng = Rng::new(2);
        let d = 64;
        let t = 160;
        let hidden: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let rows: Vec<(usize, Vec<f32>)> = [31usize, 63, 127]
            .iter()
            .map(|&p| (p, hidden[p * d..(p + 1) * d].to_vec()))
            .collect();
        let c = Commitment::build(&rows, TOPK);
        c.verify_against(&hidden, d, t).unwrap();
    }

    #[test]
    fn tolerates_small_numeric_noise() {
        // GPU nondeterminism: small relative perturbations must pass.
        let mut rng = Rng::new(3);
        let d = 64;
        let hidden: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c = Commitment::build(&[(0, hidden.clone())], TOPK);
        let noisy: Vec<f32> = hidden.iter().map(|v| v * 1.005).collect();
        c.verify_against(&noisy, d, 1).unwrap();
    }

    #[test]
    fn detects_different_weights() {
        let mut rng = Rng::new(4);
        let d = 64;
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c = Commitment::build(&[(0, a)], TOPK);
        assert!(c.verify_against(&b, d, 1).is_err());
    }

    #[test]
    fn detects_quantization() {
        // Coarse quantization (int4-ish) shifts values beyond rtol.
        let mut rng = Rng::new(5);
        let d = 64;
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c = Commitment::build(&[(0, a.clone())], TOPK);
        let q: Vec<f32> = a.iter().map(|v| v.round()).collect();
        assert!(c.verify_against(&q, d, 1).is_err());
    }

    #[test]
    fn rejects_out_of_range_positions() {
        let c = Commitment::build(&[(999, vec![1.0; 8])], 4);
        assert!(c.verify_against(&vec![0.0; 64 * 8], 8, 64).is_err());
    }

    #[test]
    fn rejects_forged_row_shapes() {
        // Attacker-shaped rows must fail, not bypass or panic: empty or
        // short rows (which would vacuously match / lower the overlap bar
        // to one known coordinate), duplicated coordinates (overlap
        // inflation), out-of-range indices (previously an
        // index-out-of-bounds panic in the validator), and NaN values
        // (which the tolerance comparison can't flag).
        let mut rng = Rng::new(7);
        let d = 64;
        let h: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let honest = Commitment::build(&[(0, h.clone())], TOPK);
        honest.verify_against(&h, d, 1).unwrap();

        let empty = Commitment { rows: vec![CommitRow { pos: 0, idx: vec![], val: vec![] }] };
        assert!(empty.verify_against(&h, d, 1).unwrap_err().contains("commit row of 0 coords"));

        let top = honest.rows[0].idx[0];
        let short = Commitment {
            rows: vec![CommitRow { pos: 0, idx: vec![top], val: vec![h[top as usize]] }],
        };
        assert!(short.verify_against(&h, d, 1).unwrap_err().contains("commit row of 1 coords"));

        let dup = Commitment {
            rows: vec![CommitRow {
                pos: 0,
                idx: vec![top; TOPK],
                val: vec![h[top as usize]; TOPK],
            }],
        };
        assert!(dup.verify_against(&h, d, 1).unwrap_err().contains("duplicate"));

        let mut forged = honest.clone();
        forged.rows[0].idx[TOPK - 1] = 1_000_000;
        assert!(forged.verify_against(&h, d, 1).unwrap_err().contains("outside d_model"));

        let mut nan = honest.clone();
        nan.rows[0].val[0] = f32::NAN;
        assert!(nan.verify_against(&h, d, 1).unwrap_err().contains("non-finite"));
    }

    #[test]
    fn topk_matches_full_sort_reference() {
        // The selection-based top-k must reproduce the stable full sort it
        // replaced, including index-ascending tie-breaks (so commitments
        // built by old and new code are interchangeable).
        let mut rng = Rng::new(6);
        for case in 0..50 {
            let d = 1 + (case * 7) % 96;
            let mut x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            // Inject deliberate |value| ties.
            if d > 4 {
                x[1] = -x[0];
                x[d / 2] = x[0];
            }
            let k = 1 + case % 12;
            let mut order: Vec<usize> = (0..x.len()).collect();
            order.sort_by(|&a, &b| {
                x[b].abs().partial_cmp(&x[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
            });
            let top = &order[..k.min(x.len())];
            let want_idx: Vec<u32> = top.iter().map(|&i| i as u32).collect();
            let want_val: Vec<f32> = top.iter().map(|&i| x[i]).collect();
            assert_eq!(topk_abs(&x, k), (want_idx, want_val), "d={d} k={k}");
        }
        assert_eq!(topk_abs(&[], 4), (Vec::new(), Vec::new()));
        assert_eq!(topk_abs(&[1.0, 2.0], 0), (Vec::new(), Vec::new()));
    }

    #[test]
    fn decode_truncation_is_error_not_panic() {
        let mut rng = Rng::new(9);
        let bytes = Commitment::build(&hidden_rows(&mut rng, 4, 16), TOPK).encode();
        for cut in 0..bytes.len() {
            assert!(Commitment::decode(&bytes[..cut]).is_err(), "prefix {cut} decoded");
        }
        assert!(Commitment::decode(&[]).is_err());
    }

    #[test]
    fn decode_mutation_fuzz_never_panics() {
        // Hostile commitments must surface as Err, never a validator panic.
        let mut rng = Rng::new(10);
        let base = Commitment::build(&hidden_rows(&mut rng, 3, 8), TOPK).encode();
        for _ in 0..500 {
            let mut b = base.clone();
            for _ in 0..1 + rng.usize(4) {
                let i = rng.usize(b.len());
                b[i] = b[i].wrapping_add(1 + rng.next_u32() as u8 % 255);
            }
            let _ = Commitment::decode(&b);
            let grown = [b.as_slice(), &[0u8; 7]].concat();
            let _ = Commitment::decode(&grown);
        }
    }
}
