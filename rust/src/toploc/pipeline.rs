//! Prefill planning for the parallel validation pipeline: cross-submission,
//! length-bucketed lane packing.
//!
//! The pre-pipeline validator padded every prefill to the full
//! `batch_infer x max_seq` frame and filled its lanes from a single
//! submission, so a 4-rollout submission wasted 12 of 16 lanes and every
//! short rollout paid for `max_seq` positions. The planner here takes all
//! rollouts that share a policy version — across submissions — sorts them
//! longest-first, packs them `batch_infer` lanes at a time, and pads each
//! call only to its longest lane rounded up to the bucket grain (the
//! TOPLOC commit interval by default, so commit-row positions always fall
//! inside the padded frame). Verdict attribution stays per submission via
//! the `(sub, rollout)` tags carried on every lane.
//!
//! This module is engine-independent (pure planning); the validator node
//! (`coordinator::validation`) executes the plan against the runtime.

// Verdict-path planning code: panics here kill the validator thread
// (swarmlint `panic-path`; clippy mirrors the gate in CI).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

/// One rollout awaiting the prefill-backed checks (stages 4–5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneReq {
    /// Caller-scoped submission slot (index into the wave being validated).
    pub sub: usize,
    /// Rollout index within that submission.
    pub rollout: usize,
    /// Sequence length in tokens (prompt + completion).
    pub len: usize,
}

/// One planned prefill call: up to `batch_infer` lanes drawn from any mix
/// of submissions (all sharing a policy version), padded to `seq_len`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlannedCall {
    /// Occupied lanes in call order (lane i of the batch holds `lanes[i]`).
    pub lanes: Vec<LaneReq>,
    /// Padded sequence length: the longest lane rounded up to the bucket
    /// grain, capped at `max_seq`. Covers every lane in the call.
    pub seq_len: usize,
}

/// Pack `lanes` (one per rollout, all sharing a policy version) into
/// prefill calls of at most `batch_infer` lanes each.
///
/// Lanes are sorted longest-first with a deterministic `(sub, rollout)`
/// tie-break, so each chunk's padding is set by its first lane and the plan
/// is a pure function of the lane set — the same wave always produces the
/// same calls regardless of arrival order or validator thread count.
/// Callers must have rejected lanes longer than `max_seq` beforehand.
pub fn plan_prefills(
    mut lanes: Vec<LaneReq>,
    batch_infer: usize,
    bucket: usize,
    max_seq: usize,
) -> Vec<PlannedCall> {
    let b = batch_infer.max(1);
    let grain = bucket.max(1);
    lanes.sort_unstable_by(|a, b| {
        b.len.cmp(&a.len).then(a.sub.cmp(&b.sub)).then(a.rollout.cmp(&b.rollout))
    });
    lanes
        .chunks(b)
        .map(|c| {
            let longest = c[0].len.min(max_seq).max(1);
            let seq_len = (longest.div_ceil(grain) * grain).min(max_seq).max(longest);
            PlannedCall { lanes: c.to_vec(), seq_len }
        })
        .collect()
}

/// Fraction of lane-token slots in `calls` not occupied by real tokens —
/// the padding waste the plan leaves on the table (benches report this;
/// the full-pad baseline's waste is `1 - Σlen / (n_calls · B · max_seq)`).
pub fn plan_padding_fraction(calls: &[PlannedCall], batch_infer: usize) -> f64 {
    // swarmlint: allow(float-fold) — usize sums; integer addition is
    // associative, only float accumulation needs a pinned order.
    let total: usize = calls.iter().map(|c| batch_infer.max(1) * c.seq_len).sum();
    if total == 0 {
        return 0.0;
    }
    // swarmlint: allow(float-fold) — usize sum, as above.
    let used: usize = calls.iter().flat_map(|c| c.lanes.iter().map(|l| l.len)).sum();
    1.0 - used as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, ensure_eq};
    use crate::util::rng::Rng;

    fn lane(sub: usize, rollout: usize, len: usize) -> LaneReq {
        LaneReq { sub, rollout, len }
    }

    #[test]
    fn packs_across_submissions_and_buckets_lengths() {
        // 3 submissions of 2 rollouts, batch of 4 lanes, grain 32.
        let lanes = vec![
            lane(0, 0, 40),
            lane(0, 1, 10),
            lane(1, 0, 100),
            lane(1, 1, 90),
            lane(2, 0, 33),
            lane(2, 1, 8),
        ];
        let calls = plan_prefills(lanes, 4, 32, 256);
        assert_eq!(calls.len(), 2);
        // Longest-first: the 100/90/40/33 lanes share the first call,
        // padded to 128 (100 rounded up to the 32 grain).
        assert_eq!(calls[0].seq_len, 128);
        assert_eq!(
            calls[0].lanes,
            vec![lane(1, 0, 100), lane(1, 1, 90), lane(0, 0, 40), lane(2, 0, 33)]
        );
        // The short tail pays only one 32-token bucket.
        assert_eq!(calls[1].seq_len, 32);
        assert_eq!(calls[1].lanes, vec![lane(0, 1, 10), lane(2, 1, 8)]);
    }

    #[test]
    fn seq_len_caps_at_max_seq() {
        let calls = plan_prefills(vec![lane(0, 0, 250)], 4, 32, 256);
        assert_eq!(calls[0].seq_len, 256);
        // Rounding lands inside the frame when it can...
        let calls = plan_prefills(vec![lane(0, 0, 90)], 4, 32, 100);
        assert_eq!(calls[0].seq_len, 96);
        // ...and caps at a max_seq that is not a multiple of the grain.
        let calls = plan_prefills(vec![lane(0, 0, 99)], 4, 32, 100);
        assert_eq!(calls[0].seq_len, 100);
    }

    #[test]
    fn padding_fraction_counts_empty_lanes() {
        // One call, 2 of 4 lanes used, padded to 32: 48/128 slots used.
        let calls = plan_prefills(vec![lane(0, 0, 32), lane(0, 1, 16)], 4, 32, 256);
        let waste = plan_padding_fraction(&calls, 4);
        assert!((waste - (1.0 - 48.0 / 128.0)).abs() < 1e-9, "waste={waste}");
        assert_eq!(plan_padding_fraction(&[], 4), 0.0);
    }

    #[test]
    fn plan_properties() {
        check(
            "prefill plan covers every lane within bounds",
            64,
            |rng, size| {
                let n_subs = 1 + rng.usize(6);
                let mut lanes = Vec::new();
                for s in 0..n_subs {
                    for r in 0..1 + rng.usize(size as usize + 3) {
                        lanes.push(lane(s, r, 1 + rng.usize(256)));
                    }
                }
                let b = 1 + rng.usize(16);
                let grain = 1 + rng.usize(64);
                (lanes, b, grain)
            },
            |(lanes, b, grain)| {
                let max_seq = 256;
                let calls = plan_prefills(lanes.clone(), *b, *grain, max_seq);
                // Every lane appears exactly once.
                let mut seen: Vec<LaneReq> = calls.iter().flat_map(|c| c.lanes.clone()).collect();
                seen.sort_unstable_by_key(|l| (l.sub, l.rollout));
                let mut want = lanes.clone();
                want.sort_unstable_by_key(|l| (l.sub, l.rollout));
                ensure_eq(seen, want, "lane coverage")?;
                for c in &calls {
                    ensure(c.lanes.len() <= *b, "call exceeds batch_infer")?;
                    ensure(c.seq_len <= max_seq, "seq_len beyond max_seq")?;
                    ensure(
                        c.seq_len % *grain == 0 || c.seq_len == max_seq,
                        "seq_len off the bucket grain",
                    )?;
                    for l in &c.lanes {
                        ensure(l.len <= c.seq_len, "lane longer than its call frame")?;
                    }
                }
                // Deterministic: arrival order must not change the plan.
                let mut shuffled = lanes.clone();
                Rng::new(0xD15C0).shuffle(&mut shuffled);
                ensure_eq(
                    plan_prefills(shuffled, *b, *grain, max_seq),
                    calls,
                    "plan depends on arrival order",
                )
            },
        );
    }
}
