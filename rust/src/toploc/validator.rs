//! The TOPLOC validator (§2.3): computation, sampling and sanity checks
//! over untrusted rollout submissions. Engine-independent logic lives
//! here; the validator *node* (coordinator::validator) feeds it prefill
//! outputs from the runtime.

// Trust-critical verdict path: hostile submissions must never panic the
// validator (swarmlint `panic-path`; clippy mirrors the gate in CI).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use super::commitment::Commitment;
use crate::rl::reward::RewardConfig;
use crate::rl::rollout_file::{Submission, WireRollout};
use crate::tasks::dataset::{node_sample_seed, Dataset};
use crate::verifier::Registry;

#[derive(Clone, Debug, PartialEq)]
pub enum Rejection {
    /// rpq parse / schema failure ("parquet formatting check").
    Schema(String),
    /// Rollouts generated with a checkpoint outside the accepted window.
    StalePolicy { submitted: u64, current: u64 },
    /// Task ids don't reproduce from the fixed sampling seed.
    SeedMismatch,
    /// Group ids don't match the deterministic per-(node, step, idx)
    /// derivation — a vector for steering rollouts into other nodes'
    /// GRPO groups.
    GroupIdMismatch { got: u64, want: u64 },
    /// Reported scalars outside plausible bounds.
    ValueBounds(String),
    /// Reported reward disagrees with re-verification.
    RewardMismatch { task_id: u64 },
    /// Neither max-length nor a plausible EOS termination.
    Termination { eos_prob: f32 },
    /// TOPLOC commitment does not match recomputed hidden states.
    Computation(String),
    /// Token sampling distribution inconsistent with the claimed model
    /// (bimodal low-probability mass — §2.3.2).
    SamplingBimodal { low_frac: f64 },
    /// Reported per-token probs disagree with recomputed probs.
    ProbMismatch { median_err: f32 },
}

#[derive(Clone, Debug)]
pub struct ValidatorConfig {
    /// §2.3.2: EOS probability must exceed this at EOS termination.
    pub eos_prob_min: f32,
    /// Sampling check: max tolerated fraction of completion tokens whose
    /// recomputed probability is below `low_prob_threshold`.
    pub low_prob_frac_max: f64,
    pub low_prob_threshold: f32,
    /// Median |reported - recomputed| token probability tolerance.
    pub prob_median_tol: f32,
    /// Accept rollouts from checkpoints at most this many steps behind.
    pub max_policy_lag: u64,
    /// Group size each submission must carry per prompt.
    pub expected_group: usize,
    /// Hard cap on rollouts per submission (0 = unlimited). The seed
    /// check pins *which* tasks a submission of a given size must carry,
    /// but the task stream is prefix-stable — nothing stops a node from
    /// drawing more prompts than its quota and claiming reward on all of
    /// them. Stake sizing (`protocol::min_negative_ev_stake`) assumes a
    /// bounded reward per submission, so the swarm sets this to the
    /// per-worker quota and the validator enforces it — on the full path
    /// and on the sampling gate's spot-check-exempt path alike.
    pub max_rollouts_per_sub: usize,
}

impl Default for ValidatorConfig {
    fn default() -> Self {
        ValidatorConfig {
            eos_prob_min: 0.1,
            low_prob_frac_max: 0.30,
            // Well below uniform (1/vocab ~ 0.016): honest sampling from a
            // near-uniform policy stays above this; decode-with-a-different-
            // model lands orders of magnitude below it.
            low_prob_threshold: 0.002,
            prob_median_tol: 0.10,
            max_policy_lag: 5,
            expected_group: 4,
            max_rollouts_per_sub: 0,
        }
    }
}

pub struct Validator {
    pub cfg: ValidatorConfig,
    pub registry: std::sync::Arc<Registry>,
}

impl Validator {
    /// Validator over the standard environment registry.
    pub fn new(cfg: ValidatorConfig) -> Validator {
        Validator { cfg, registry: std::sync::Arc::new(Registry::default()) }
    }

    /// Validator over a custom registry (plugin deployments). The
    /// validation pipeline checks its fingerprint against the dataset's at
    /// construction — reward re-verification under mismatched env
    /// semantics would slash honest workers.
    pub fn with_registry(cfg: ValidatorConfig, registry: std::sync::Arc<Registry>) -> Validator {
        Validator { cfg, registry }
    }

    /// Stage 1 — file-level checks: decode + schema ("parquet check").
    pub fn check_file(&self, bytes: &[u8]) -> Result<Submission, Rejection> {
        Submission::decode(bytes).map_err(|e| Rejection::Schema(e.to_string()))
    }

    /// Stage 2 — sanity checks (§2.3.3): fixed data sampling, value bounds,
    /// reward re-verification, staleness.
    pub fn check_sanity(
        &self,
        sub: &Submission,
        dataset: &Dataset,
        reward_cfg: &RewardConfig,
        current_step: u64,
        max_completion: usize,
    ) -> Result<(), Rejection> {
        self.sanity_checks(sub, dataset, reward_cfg, current_step, max_completion, true)
    }

    /// The cheap deterministic subset of stage 2, for the sampling gate's
    /// spot-check-exempt path: staleness, the per-submission rollout cap,
    /// fixed-data-sampling seed match, group-id enforcement, and every
    /// value-bounds check — everything except the env reward replay (the
    /// one stage-2 check whose cost scales with completion length).
    /// Sampling may only buy a pass on *expensive* re-verification; a
    /// skipped upload that fails any of these carries a provable lie and
    /// is slashed like a fully-verified one, so claimed rewards admitted
    /// on trust stay bounded by exactly the assumptions
    /// [`protocol::min_negative_ev_stake`](crate::protocol::min_negative_ev_stake)
    /// sizes stakes under.
    pub fn check_sanity_pre(
        &self,
        sub: &Submission,
        dataset: &Dataset,
        reward_cfg: &RewardConfig,
        current_step: u64,
        max_completion: usize,
    ) -> Result<(), Rejection> {
        self.sanity_checks(sub, dataset, reward_cfg, current_step, max_completion, false)
    }

    /// Shared stage-2 body. `replay_rewards` gates only the env reward
    /// re-verification; check order is otherwise identical on both paths
    /// so full-pipeline verdicts never depend on which caller ran first.
    fn sanity_checks(
        &self,
        sub: &Submission,
        dataset: &Dataset,
        reward_cfg: &RewardConfig,
        current_step: u64,
        max_completion: usize,
        replay_rewards: bool,
    ) -> Result<(), Rejection> {
        if sub.step + self.cfg.max_policy_lag < current_step {
            return Err(Rejection::StalePolicy { submitted: sub.step, current: current_step });
        }
        // Per-submission volume cap: bounds the reward a single upload can
        // claim, which the negative-EV stake sizing relies on.
        let cap = self.cfg.max_rollouts_per_sub;
        if cap > 0 && sub.rollouts.len() > cap {
            return Err(Rejection::ValueBounds(format!(
                "{} rollouts exceeds per-submission cap {cap}",
                sub.rollouts.len()
            )));
        }
        // Fixed data sampling: reproduce the node's draw. Each sampled task
        // id must appear expected_group times (grouped by prompt).
        let seed = node_sample_seed(sub.node_address, sub.step, sub.submission_idx);
        let n_prompts = sub.rollouts.len() / self.cfg.expected_group.max(1);
        let expect = dataset.sample_for(seed, n_prompts);
        let mut want = Vec::new();
        for id in expect {
            for _ in 0..self.cfg.expected_group {
                want.push(id);
            }
        }
        let got: Vec<u64> = sub.rollouts.iter().map(|r| r.rollout.task_id).collect();
        if got != want {
            return Err(Rejection::SeedMismatch);
        }
        // Group ids are as deterministic as the task draw: base hash of
        // (node, step, idx) plus the prompt index. Enforcing them here
        // closes the deliberate-collision vector (a node claiming another
        // node's group ids to poison its advantage baselines).
        let base = crate::rl::group_id_base(sub.node_address, sub.step, sub.submission_idx);
        for (i, w) in sub.rollouts.iter().enumerate() {
            let want_gid = base + (i / self.cfg.expected_group.max(1)) as u64;
            if w.rollout.group_id != want_gid {
                return Err(Rejection::GroupIdMismatch {
                    got: w.rollout.group_id,
                    want: want_gid,
                });
            }
        }

        for w in &sub.rollouts {
            let r = &w.rollout;
            // The wire schema only guarantees prompt_len < max(len, 1), so
            // a crafted rollout can arrive with no tokens at all — reject
            // before the slicing below can panic on it.
            if r.tokens.is_empty() {
                return Err(Rejection::ValueBounds("empty token list".into()));
            }
            // A zero prompt_len would send the sampling check to the
            // logits row at position -1 (usize underflow); honest prompts
            // always lead with BOS, so prompt_len >= 1.
            if r.prompt_len == 0 {
                return Err(Rejection::ValueBounds("zero prompt_len".into()));
            }
            if !crate::rl::reward::reward_in_bounds(reward_cfg, r.reward, max_completion) {
                return Err(Rejection::ValueBounds(format!("reward {}", r.reward)));
            }
            if !r.sampled_probs.iter().all(|p| (0.0..=1.0).contains(p) && p.is_finite()) {
                return Err(Rejection::ValueBounds("sampled prob outside [0,1]".into()));
            }
            if r.sampled_probs.len() != r.completion_len() {
                return Err(Rejection::ValueBounds("probs / completion length mismatch".into()));
            }
            // Special tokens must not appear inside the body (a PAD would
            // corrupt prefill segmentation; BOS only leads).
            if r.tokens[1..].iter().any(|&t| {
                t == crate::data::tokenizer::PAD
                    || t == crate::data::tokenizer::BOS
                    || !(0..crate::data::tokenizer::VOCAB_SIZE as i32).contains(&t)
            }) {
                return Err(Rejection::ValueBounds("illegal token id in sequence".into()));
            }
            // Re-verify the claimed task reward against the environment —
            // the one expensive stage-2 check, and the only one the
            // sampling gate's skip path is allowed to defer to spot
            // checks. The task lookup itself stays on both paths (a
            // nonexistent task id is a cheap, deterministic lie).
            let task = match dataset.get(r.task_id) {
                Some(t) => t,
                None => return Err(Rejection::ValueBounds(format!("unknown task {}", r.task_id))),
            };
            if replay_rewards {
                let completion = crate::data::tokenizer::decode_clean(&r.tokens[r.prompt_len..]);
                let want_reward = crate::rl::reward::task_reward(&self.registry, task, &completion);
                if (want_reward - r.task_reward).abs() > 1e-4 {
                    return Err(Rejection::RewardMismatch { task_id: r.task_id });
                }
            }
        }
        Ok(())
    }

    /// Stage 3 — termination check (§2.3.2).
    pub fn check_termination(&self, w: &WireRollout, max_new: usize, max_seq: usize) -> Result<(), Rejection> {
        if w.finish_eos {
            let last = *w.rollout.tokens.last().unwrap_or(&-1);
            if last != crate::data::tokenizer::EOS || w.eos_prob <= self.cfg.eos_prob_min {
                return Err(Rejection::Termination { eos_prob: w.eos_prob });
            }
            Ok(())
        } else {
            // Claimed max-length termination must actually be at the limit
            // (premature truncation saves the provider compute — §2.3.2).
            let len = w.rollout.completion_len();
            if len >= max_new || w.rollout.tokens.len() >= max_seq - 1 {
                Ok(())
            } else {
                Err(Rejection::Termination { eos_prob: 0.0 })
            }
        }
    }

    /// Stage 4 — computation check (§2.3.1): TOPLOC commitment vs hidden
    /// states recomputed by prefill (`hidden` row-major `[T, d_model]`).
    pub fn check_computation(
        &self,
        w: &WireRollout,
        hidden: &[f32],
        d_model: usize,
    ) -> Result<(), Rejection> {
        let c = Commitment::decode(&w.commitment)
            .map_err(|e| Rejection::Computation(e.to_string()))?;
        c.verify_against(hidden, d_model, w.rollout.tokens.len())
            .map_err(Rejection::Computation)
    }

    /// Stage 5 — token sampling checks (§2.3.2) from prefill logits
    /// (`logits` row-major `[T, vocab]`). Detects decode-with-smaller-model
    /// (bimodal probability of sampled tokens under the claimed model) and
    /// fabricated probability reports.
    pub fn check_sampling(
        &self,
        w: &WireRollout,
        logits: &[f32],
        vocab: usize,
    ) -> Result<(), Rejection> {
        let r = &w.rollout;
        if r.completion_len() == 0 {
            return Ok(());
        }
        // Calibrated bimodality test: under honest sampling, the expected
        // number of tokens with p < t equals the summed tail mass below t
        // of the model's own distributions. A worker decoding with a
        // different (smaller) model lands most tokens in the claimed
        // model's low tail — observed >> expected.
        //
        // Validator hot loop: p(sampled), the tail mass and the reported-
        // prob error are computed in two passes over each vocab row with no
        // per-token allocation (previously: separate max / normalizer /
        // materialized-probability-vector / tail-filter passes plus a
        // Vec<f64> per completion token). The exact tail mass needs the
        // softmax normalizer first, so two passes is the floor; the first
        // pass folds max and normalizer together with online rescaling.
        let t = self.cfg.low_prob_threshold;
        let mut low = 0usize;
        let mut expected_low = 0.0f64;
        let mut errs: Vec<f32> = Vec::with_capacity(r.completion_len());
        for j in 0..r.completion_len() {
            let pos = r.prompt_len + j; // token index being predicted
            let row = &logits[(pos - 1) * vocab..pos * vocab];
            // Pass 1: streaming softmax — running max m and z = Σ exp(l-m).
            let mut m = f32::NEG_INFINITY;
            let mut z = 0.0f64;
            for &l in row {
                if l > m {
                    z = z * ((m - l) as f64).exp() + 1.0;
                    m = l;
                } else if l > f32::NEG_INFINITY || m > f32::NEG_INFINITY {
                    z += ((l - m) as f64).exp();
                }
                // else: both -inf — contributes nothing, and (l - m)
                // would be NaN and poison z (the old global-max code
                // treated -inf logits as probability 0; keep that).
            }
            // A row of all -inf (or logits pushed until exp overflows the
            // rescaled normalizer) makes every q below NaN or inf. Those
            // NaNs would flow into the tail expectation and the median,
            // where `NaN > tol` is false — i.e. a hostile row would *pass*
            // every later comparison. Reject the row outright instead.
            if !z.is_finite() || z <= 0.0 {
                return Err(Rejection::ValueBounds(format!(
                    "non-finite softmax normalizer at position {pos}"
                )));
            }
            // Pass 2: p(sampled) and the sub-threshold tail mass.
            let sampled = r.tokens[pos] as usize;
            let mut p = 0.0f32;
            let mut tail = 0.0f64;
            for (i, &l) in row.iter().enumerate() {
                let q = ((l - m) as f64).exp() / z;
                if q < t as f64 {
                    tail += q;
                }
                if i == sampled {
                    p = q as f32;
                }
            }
            expected_low += tail;
            if p < t {
                low += 1;
            }
            errs.push((p - r.sampled_probs[j]).abs());
        }
        let n = r.completion_len() as f64;
        // Slack: 3x the expectation plus an absolute allowance, so short
        // honest completions with a couple of rare draws pass.
        if (low as f64) > 3.0 * expected_low + 0.25 * n + 2.0 {
            return Err(Rejection::SamplingBimodal { low_frac: low as f64 / n });
        }
        // Median via selection instead of a full sort of the error vector.
        // `total_cmp`, not `partial_cmp(..).unwrap()`: any NaN an attacker
        // sneaks into the error vector sorts largest instead of panicking
        // the validator mid-verdict.
        let mid = errs.len() / 2;
        let (_, median, _) = errs.select_nth_unstable_by(mid, |a, b| a.total_cmp(b));
        let median = *median;
        if median > self.cfg.prob_median_tol {
            return Err(Rejection::ProbMismatch { median_err: median });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::rollout_file::WireRollout;
    use crate::rl::Rollout;
    use crate::tasks::dataset::DatasetConfig;

    fn wire(tokens: Vec<i32>, prompt_len: usize, finish_eos: bool, eos_prob: f32) -> WireRollout {
        let n = tokens.len() - prompt_len;
        WireRollout {
            rollout: Rollout {
                task_id: 0,
                group_id: 0,
                policy_step: 0,
                tokens,
                prompt_len,
                target_len: None,
                task_reward: 0.0,
                length_penalty: 0.0,
                reward: 0.0,
                advantage: 0.0,
                sampled_probs: vec![0.3; n],
                node_address: 1,
            },
            commitment: Commitment::default().encode(),
            finish_eos,
            eos_prob,
        }
    }

    #[test]
    fn termination_check() {
        let v = Validator::new(ValidatorConfig::default());
        let eos = crate::data::tokenizer::EOS;
        // Good EOS.
        let w = wire(vec![1, 5, 6, eos], 2, true, 0.6);
        v.check_termination(&w, 64, 256).unwrap();
        // EOS with implausible probability.
        let w = wire(vec![1, 5, 6, eos], 2, true, 0.01);
        assert!(matches!(v.check_termination(&w, 64, 256), Err(Rejection::Termination { .. })));
        // Claimed EOS but last token isn't EOS.
        let w = wire(vec![1, 5, 6, 7], 2, true, 0.9);
        assert!(v.check_termination(&w, 64, 256).is_err());
        // Premature "max length" truncation.
        let w = wire(vec![1, 5, 6, 7], 2, false, 0.0);
        assert!(v.check_termination(&w, 64, 256).is_err());
        // Genuine max length.
        let toks: Vec<i32> = (0..66).map(|i| 3 + i % 50).collect();
        let w = wire(toks, 2, false, 0.0);
        v.check_termination(&w, 64, 256).unwrap();
    }

    #[test]
    fn sampling_check_accepts_consistent_probs() {
        let v = Validator::new(ValidatorConfig::default());
        let vocab = 8;
        // Logits: uniform, so every token has p = 1/8 = 0.125.
        let mut w = wire(vec![1, 3, 4, 5, 6], 1, false, 0.0);
        w.rollout.sampled_probs = vec![0.125; 4];
        let logits = vec![0.0f32; 5 * vocab];
        v.check_sampling(&w, &logits, vocab).unwrap();
    }

    #[test]
    fn sampling_check_tolerates_neg_infinity_logits() {
        // A row *leading* with -inf seeds the streaming pass with
        // m = l = -inf; the rescale must not poison z with NaN (which
        // would panic the median selection). -inf logits are probability
        // 0, as the old global-max implementation computed.
        let v = Validator::new(ValidatorConfig::default());
        let vocab = 8;
        let mut w = wire(vec![1, 3, 4, 5, 6], 1, false, 0.0);
        let mut logits = vec![0.0f32; 5 * vocab];
        for t in 0..5 {
            logits[t * vocab] = f32::NEG_INFINITY;
        }
        // Mass is uniform over the remaining 7 tokens.
        w.rollout.sampled_probs = vec![1.0 / 7.0; 4];
        v.check_sampling(&w, &logits, vocab).unwrap();
    }

    #[test]
    fn sampling_check_rejects_all_neg_infinity_row() {
        // An entire row of -inf gives z = 0; every q would be NaN, and
        // since `NaN > tol` is false the row would slip past both the
        // bimodality and the median comparison. It must reject instead.
        let v = Validator::new(ValidatorConfig::default());
        let vocab = 8;
        let mut w = wire(vec![1, 3, 4, 5, 6], 1, false, 0.0);
        w.rollout.sampled_probs = vec![0.125; 4];
        let mut logits = vec![0.0f32; 5 * vocab];
        for x in &mut logits[2 * vocab..3 * vocab] {
            *x = f32::NEG_INFINITY;
        }
        match v.check_sampling(&w, &logits, vocab) {
            Err(Rejection::ValueBounds(msg)) => assert!(msg.contains("normalizer")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sampling_check_rejects_bimodal() {
        let mut cfg = ValidatorConfig::default();
        cfg.prob_median_tol = 10.0; // isolate the bimodality check
        let v = Validator::new(cfg);
        let vocab = 8;
        // Claimed model strongly prefers token 7 everywhere; the submitted
        // tokens are all token 3 -> recomputed p(sampled) ~ 0.
        let mut logits = vec![0.0f32; 12 * vocab];
        for t in 0..12 {
            logits[t * vocab + 7] = 10.0;
        }
        let w = wire(vec![1, 3, 3, 3, 3, 3, 3, 3], 1, false, 0.0);
        match v.check_sampling(&w, &logits, vocab) {
            Err(Rejection::SamplingBimodal { low_frac }) => assert!(low_frac > 0.9),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sampling_check_rejects_fabricated_probs() {
        let v = Validator::new(ValidatorConfig::default());
        let vocab = 8;
        let mut w = wire(vec![1, 3, 4, 5, 6], 1, false, 0.0);
        w.rollout.sampled_probs = vec![0.9; 4]; // actual is 0.125
        let logits = vec![0.0f32; 5 * vocab];
        assert!(matches!(
            v.check_sampling(&w, &logits, vocab),
            Err(Rejection::ProbMismatch { .. })
        ));
    }

    #[test]
    fn sanity_seed_and_reward_checks() {
        let v = Validator::new(ValidatorConfig { expected_group: 2, ..Default::default() });
        let dataset = Dataset::generate(
            &Registry::standard(),
            &DatasetConfig {
                mix: crate::tasks::dataset::EnvMix::of(&[("math", 40)]),
                ..Default::default()
            },
        )
        .unwrap();
        let reward_cfg = RewardConfig::default();

        // Build an honest submission: tasks drawn from the seed formula,
        // group ids from the deterministic base.
        let seed = node_sample_seed(9, 3, 0);
        let base = crate::rl::group_id_base(9, 3, 0);
        let ids = dataset.sample_for(seed, 2);
        let mut rollouts = Vec::new();
        for (pi, id) in ids.iter().enumerate() {
            let task = dataset.get(*id).unwrap();
            for _ in 0..2 {
                let mut tokens = vec![crate::data::tokenizer::BOS];
                tokens.extend(crate::data::tokenizer::encode(&task.prompt));
                let plen = tokens.len();
                tokens.extend(crate::data::tokenizer::encode(task.answer()));
                tokens.push(crate::data::tokenizer::EOS);
                let n = tokens.len() - plen;
                let mut w = wire(tokens, plen, true, 0.9);
                w.rollout.task_id = *id;
                w.rollout.group_id = base + pi as u64;
                w.rollout.task_reward = 1.0;
                w.rollout.reward = 1.0;
                w.rollout.sampled_probs = vec![0.5; n];
                rollouts.push(w);
            }
        }
        let sub = Submission { node_address: 9, step: 3, submission_idx: 0, rollouts };
        v.check_sanity(&sub, &dataset, &reward_cfg, 3, 128).unwrap();

        // Claiming someone else's group ids (deliberate collision attack).
        let mut gid_thief = sub.clone();
        gid_thief.rollouts[2].rollout.group_id = crate::rl::group_id_base(8, 3, 0);
        assert!(matches!(
            v.check_sanity(&gid_thief, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::GroupIdMismatch { .. })
        ));

        // Cherry-picking: swap in a different task id.
        let mut cheat = sub.clone();
        cheat.rollouts[0].rollout.task_id = (ids[0] + 1) % dataset.len() as u64;
        assert_eq!(
            v.check_sanity(&cheat, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::SeedMismatch)
        );

        // Lying about rewards.
        let mut liar = sub.clone();
        liar.rollouts[0].rollout.task_reward = 0.0;
        liar.rollouts[0].rollout.reward = 0.0;
        assert!(matches!(
            v.check_sanity(&liar, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::RewardMismatch { .. })
        ));

        // Stale policy.
        assert!(matches!(
            v.check_sanity(&sub, &dataset, &reward_cfg, 99, 128),
            Err(Rejection::StalePolicy { .. })
        ));

        // Out-of-bounds reward.
        let mut bounds = sub.clone();
        bounds.rollouts[1].rollout.reward = 42.0;
        assert!(matches!(
            v.check_sanity(&bounds, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::ValueBounds(_))
        ));

        // Empty token list: decodes (prompt_len 0 < max(len, 1)) but must
        // be rejected, not panic the special-token slice below it.
        let mut hollow = sub.clone();
        hollow.rollouts[1].rollout.tokens = Vec::new();
        hollow.rollouts[1].rollout.prompt_len = 0;
        hollow.rollouts[1].rollout.sampled_probs = Vec::new();
        assert!(matches!(
            v.check_sanity(&hollow, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::ValueBounds(_))
        ));

        // Zero prompt_len with real tokens: would underflow the sampling
        // check's position arithmetic — rejected here instead.
        let mut headless = sub.clone();
        let n_toks = headless.rollouts[0].rollout.tokens.len();
        headless.rollouts[0].rollout.prompt_len = 0;
        headless.rollouts[0].rollout.sampled_probs = vec![0.5; n_toks];
        assert!(matches!(
            v.check_sanity(&headless, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::ValueBounds(_))
        ));
    }

    /// Build an honest submission for the cap / cheap-subset tests:
    /// `n_prompts` tasks from the seed formula, `cheat_rewards` fabricates
    /// completions while still claiming 1.0 (the lie only the expensive
    /// reward replay can catch).
    fn seeded_submission(
        dataset: &Dataset,
        n_prompts: usize,
        group: usize,
        cheat_rewards: bool,
    ) -> Submission {
        let seed = node_sample_seed(9, 3, 0);
        let base = crate::rl::group_id_base(9, 3, 0);
        let ids = dataset.sample_for(seed, n_prompts);
        let mut rollouts = Vec::new();
        for (pi, id) in ids.iter().enumerate() {
            let task = dataset.get(*id).unwrap();
            for _ in 0..group {
                let mut tokens = vec![crate::data::tokenizer::BOS];
                tokens.extend(crate::data::tokenizer::encode(&task.prompt));
                let plen = tokens.len();
                if cheat_rewards {
                    tokens.extend(crate::data::tokenizer::encode("wrong"));
                } else {
                    tokens.extend(crate::data::tokenizer::encode(task.answer()));
                }
                tokens.push(crate::data::tokenizer::EOS);
                let n = tokens.len() - plen;
                let mut w = wire(tokens, plen, true, 0.9);
                w.rollout.task_id = *id;
                w.rollout.group_id = base + pi as u64;
                w.rollout.task_reward = 1.0;
                w.rollout.reward = 1.0;
                w.rollout.sampled_probs = vec![0.5; n];
                rollouts.push(w);
            }
        }
        Submission { node_address: 9, step: 3, submission_idx: 0, rollouts }
    }

    #[test]
    fn rollout_cap_bounds_claimable_reward_per_submission() {
        let dataset = Dataset::generate(
            &Registry::standard(),
            &DatasetConfig {
                mix: crate::tasks::dataset::EnvMix::of(&[("math", 40)]),
                ..Default::default()
            },
        )
        .unwrap();
        let reward_cfg = RewardConfig::default();
        let v = Validator::new(ValidatorConfig {
            expected_group: 2,
            max_rollouts_per_sub: 4,
            ..Default::default()
        });

        // At the quota: passes both the full and the cheap path.
        let quota = seeded_submission(&dataset, 2, 2, false);
        v.check_sanity(&quota, &dataset, &reward_cfg, 3, 128).unwrap();
        v.check_sanity_pre(&quota, &dataset, &reward_cfg, 3, 128).unwrap();

        // Inflated: the task stream is prefix-stable, so the extra prompts
        // still match the seed draw — only the cap stops the submission
        // from claiming unbounded reward units. Both paths reject.
        let inflated = seeded_submission(&dataset, 8, 2, false);
        for r in [
            v.check_sanity(&inflated, &dataset, &reward_cfg, 3, 128),
            v.check_sanity_pre(&inflated, &dataset, &reward_cfg, 3, 128),
        ] {
            match r {
                Err(Rejection::ValueBounds(msg)) => assert!(msg.contains("cap"), "{msg}"),
                other => panic!("inflated submission not capped: {other:?}"),
            }
        }

        // Uncapped config (0) keeps legacy behavior.
        let v0 = Validator::new(ValidatorConfig { expected_group: 2, ..Default::default() });
        v0.check_sanity(&inflated, &dataset, &reward_cfg, 3, 128).unwrap();
    }

    #[test]
    fn cheap_subset_catches_everything_but_reward_lies() {
        let dataset = Dataset::generate(
            &Registry::standard(),
            &DatasetConfig {
                mix: crate::tasks::dataset::EnvMix::of(&[("math", 40)]),
                ..Default::default()
            },
        )
        .unwrap();
        let reward_cfg = RewardConfig::default();
        let v = Validator::new(ValidatorConfig { expected_group: 2, ..Default::default() });
        let sub = seeded_submission(&dataset, 2, 2, false);

        // A fabricated completion claimed at 1.0 is exactly what the
        // cheap subset is allowed to miss (spot checks + stake cover it)…
        let liar = seeded_submission(&dataset, 2, 2, true);
        v.check_sanity_pre(&liar, &dataset, &reward_cfg, 3, 128).unwrap();
        assert!(matches!(
            v.check_sanity(&liar, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::RewardMismatch { .. })
        ));

        // …while every deterministic lie still rejects without any replay.
        let mut bounds = sub.clone();
        bounds.rollouts[1].rollout.reward = 1e30;
        assert!(matches!(
            v.check_sanity_pre(&bounds, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::ValueBounds(_))
        ));
        let mut thief = sub.clone();
        thief.rollouts[2].rollout.group_id = crate::rl::group_id_base(8, 3, 0);
        assert!(matches!(
            v.check_sanity_pre(&thief, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::GroupIdMismatch { .. })
        ));
        let mut cherry = sub.clone();
        cherry.rollouts[0].rollout.task_id += 1;
        assert!(matches!(
            v.check_sanity_pre(&cherry, &dataset, &reward_cfg, 3, 128),
            Err(Rejection::SeedMismatch)
        ));
        assert!(matches!(
            v.check_sanity_pre(&sub, &dataset, &reward_cfg, 99, 128),
            Err(Rejection::StalePolicy { .. })
        ));
    }
}
