//! Tiny CLI argument parser (`--key value`, `--flag`, positionals).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Boolean knob with a default: `--key` or `--key true` turns it on,
    /// `--key false` (or `0`/`off`) turns it off — the form on-by-default
    /// settings need, which `has_flag` alone cannot express.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        if self.has_flag(key) {
            return true;
        }
        match self.get(key) {
            Some("true") | Some("1") | Some("on") => true,
            Some("false") | Some("0") | Some("off") => false,
            _ => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed() {
        // `--key value` consumes the next token; bare flags go last or
        // use `--key=value` form.
        let a = parse("run --size nano --steps=10 input.txt --verbose");
        assert_eq!(a.positional, vec!["run", "input.txt"]);
        assert_eq!(a.get("size"), Some("nano"));
        assert_eq!(a.u64_or("steps", 0), 10);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.f64_or("lr", 0.5), 0.5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--fast");
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("--a --b v");
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn bool_knob_forms() {
        let a = parse("--x false --y --z true");
        assert!(!a.bool_or("x", true));
        assert!(a.bool_or("y", false));
        assert!(a.bool_or("z", false));
        assert!(a.bool_or("absent", true));
        assert!(!a.bool_or("absent", false));
    }
}
