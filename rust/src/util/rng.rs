//! Deterministic PRNG (SplitMix64 seeding + xoshiro256++), plus sampling
//! helpers. Every stochastic decision in the system (task sampling, node
//! churn, token sampling, network jitter) flows through this so runs are
//! exactly reproducible from a single seed — the property the paper's
//! fixed-data-sampling sanity check (§2.3.3) relies on.

/// xoshiro256++ with SplitMix64 initialization.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (paper §2.3.3: `seed = node_address *
    /// step + submissions` — we fold stream ids the same way).
    pub fn fold(&self, stream: u64) -> Rng {
        Rng::new(self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, hi: usize) -> usize {
        debug_assert!(hi > 0);
        (self.next_u64() % hi as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate 1.
    pub fn exp(&mut self) -> f64 {
        -self.f64().max(1e-300).ln()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        // Canonical left-to-right fold (swarmlint `float-fold`): sampling
        // feeds slashable token streams, so accumulation order is pinned.
        let total: f64 = crate::util::numeric::fold_f64(weights.iter().copied());
        if total <= 0.0 {
            return self.usize(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Temperature softmax sampling over logits (token sampling on the
    /// inference workers). Returns (index, probability of that index).
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> (usize, f32) {
        let t = temperature.max(1e-4);
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = logits.iter().map(|&l| (((l - max) / t) as f64).exp()).collect();
        let z: f64 = crate::util::numeric::fold_f64(exps.iter().copied());
        let idx = self.weighted(&exps);
        // Report the *untempered* model probability (what TOPLOC's sampling
        // checks reason about).
        let exps1: Vec<f64> = logits.iter().map(|&l| ((l - max) as f64).exp()).collect();
        let z1: f64 = crate::util::numeric::fold_f64(exps1.iter().copied());
        let _ = z;
        (idx, (exps1[idx] / z1) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fold_streams_differ() {
        let base = Rng::new(1);
        let mut a = base.fold(1);
        let mut b = base.fold(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let mut counts = [0u32; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 4);
    }

    #[test]
    fn sample_logits_argmax_at_low_temperature() {
        let mut r = Rng::new(6);
        let logits = vec![0.0, 5.0, 1.0];
        for _ in 0..50 {
            let (i, p) = r.sample_logits(&logits, 0.01);
            assert_eq!(i, 1);
            assert!(p > 0.9);
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }
}
