//! Panic-free little-endian byte cursor for parsing untrusted wire bytes.
//!
//! Every reader returns `Option`: a truncated or malformed buffer surfaces
//! as `None` for the caller to turn into a reject verdict, never as an
//! out-of-bounds panic (swarmlint rule `panic-path` — a panicking validator
//! is an unslashable denial of service on the audit loop).

/// Forward-only reader over an untrusted byte slice.
pub struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(bytes: &'a [u8]) -> Cursor<'a> {
        Cursor { bytes, pos: 0 }
    }

    /// Bytes consumed so far.
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Bytes left unread.
    pub fn remaining(&self) -> usize {
        self.bytes.len().saturating_sub(self.pos)
    }

    /// Next `n` bytes, advancing past them.
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let s = self.bytes.get(self.pos..end)?;
        self.pos = end;
        Some(s)
    }

    /// Fixed-size array read (the `try_into` that cannot be mis-sized).
    pub fn array<const N: usize>(&mut self) -> Option<[u8; N]> {
        let s = self.take(N)?;
        let mut out = [0u8; N];
        out.copy_from_slice(s);
        Some(out)
    }

    pub fn u8(&mut self) -> Option<u8> {
        Some(self.array::<1>()?[0])
    }

    pub fn u16_le(&mut self) -> Option<u16> {
        Some(u16::from_le_bytes(self.array()?))
    }

    pub fn u32_le(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.array()?))
    }

    pub fn u64_le(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.array()?))
    }

    pub fn f32_le(&mut self) -> Option<f32> {
        Some(f32::from_le_bytes(self.array()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_in_order() {
        let mut b = Vec::new();
        b.extend_from_slice(&7u16.to_le_bytes());
        b.extend_from_slice(&9u32.to_le_bytes());
        b.extend_from_slice(&11u64.to_le_bytes());
        b.extend_from_slice(&1.5f32.to_le_bytes());
        b.push(42);
        let mut c = Cursor::new(&b);
        assert_eq!(c.u16_le(), Some(7));
        assert_eq!(c.u32_le(), Some(9));
        assert_eq!(c.u64_le(), Some(11));
        assert_eq!(c.f32_le(), Some(1.5));
        assert_eq!(c.u8(), Some(42));
        assert_eq!(c.remaining(), 0);
        assert_eq!(c.u8(), None);
    }

    #[test]
    fn truncation_is_none_not_panic() {
        for len in 0..8 {
            let b = vec![0u8; len];
            let mut c = Cursor::new(&b);
            assert_eq!(c.u64_le(), None, "len {len}");
            // A failed read consumes nothing.
            assert_eq!(c.offset(), 0);
        }
    }

    #[test]
    fn take_past_end_is_none() {
        let b = [1u8, 2, 3];
        let mut c = Cursor::new(&b);
        assert_eq!(c.take(2), Some(&[1u8, 2][..]));
        assert_eq!(c.take(2), None);
        assert_eq!(c.take(1), Some(&[3u8][..]));
        assert_eq!(c.take(usize::MAX), None); // overflow-safe
    }
}
