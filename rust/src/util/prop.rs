//! Property-based testing helper (proptest is not in the offline crate set).
//!
//! `check(name, cases, |rng| gen, |input| prop)` runs `cases` randomized
//! trials; on failure it retries with progressively "smaller" regenerations
//! (halved size hint) and reports the reproducing seed. Seed override:
//! `I2_PROP_SEED=<n>`.

use crate::util::rng::Rng;

pub struct Config {
    pub cases: u64,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("I2_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x17e11ec2u64);
        Config { cases: 64, seed }
    }
}

/// Run a property over randomized inputs. `gen` receives (rng, size_hint in
/// [1, 100]) so generators can scale their outputs; failures report the
/// case seed for reproduction.
pub fn check_sized<T: std::fmt::Debug>(
    name: &str,
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng, u64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(case_seed);
        let size = 1 + (case * 100 / cfg.cases.max(1)).min(99);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink attempt: regenerate at smaller sizes from the same seed
            // family and report the smallest failing example found.
            let mut smallest: (u64, T, String) = (size, input, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut r2 = Rng::new(case_seed ^ s);
                let cand = gen(&mut r2, s);
                if let Err(m2) = prop(&cand) {
                    smallest = (s, cand, m2);
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed}, size {}):\n  input: {:?}\n  error: {}",
                smallest.0, smallest.1, smallest.2
            );
        }
    }
}

pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    gen: impl FnMut(&mut Rng, u64) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    let cfg = Config { cases, ..Config::default() };
    check_sized(name, &cfg, gen, prop);
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn ensure_eq<A: PartialEq + std::fmt::Debug>(a: A, b: A, msg: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{msg}: {a:?} != {b:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("reverse twice is identity", 32, |rng, size| {
            (0..size).map(|_| rng.next_u32()).collect::<Vec<_>>()
        }, |xs| {
            let mut r = xs.clone();
            r.reverse();
            r.reverse();
            ensure_eq(r, xs.clone(), "roundtrip")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 4, |rng, _| rng.next_u32(), |_| {
            Err("nope".to_string())
        });
    }
}
