//! Minimal JSON implementation (parser + writer). Used for API payloads,
//! `artifacts/<size>/spec.json`, and metrics JSONL. No serde in the offline
//! crate set, so this is a from-scratch substrate (DESIGN.md).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `spec.path(&["model", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Byte blobs (signatures, digests) hex-encode into a string. They
    /// must never ride [`Json::Num`]: numbers here are f64, exact only up
    /// to 2^53, so anything wider than 48-bit node addresses would be
    /// silently mangled (see `protocol::identity::ADDRESS_MASK`).
    pub fn hex(bytes: &[u8]) -> Json {
        Json::Str(hex_string(bytes))
    }

    /// Decode a [`Json::hex`]-encoded string back into bytes. `None` for
    /// non-strings, odd lengths or non-hex characters.
    pub fn as_hex_bytes(&self) -> Option<Vec<u8>> {
        let s = self.as_str()?;
        if s.len() % 2 != 0 {
            return None;
        }
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(s.get(i..i + 2)?, 16).ok())
            .collect()
    }

    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Canonical lowercase hex encoding for byte blobs — the single
/// implementation behind [`Json::hex`] and `shardcast::manifest::hex`.
pub fn hex_string(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.path(&["b", "c"]).unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64().unwrap(), -300.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn builder_helpers() {
        let v = Json::obj(vec![("x", 1u64.into()), ("y", "s".into())]);
        assert_eq!(v.get("x").unwrap().as_u64().unwrap(), 1);
        assert_eq!(v.get("y").unwrap().as_str().unwrap(), "s");
    }

    #[test]
    fn byte_blobs_hex_roundtrip_lossless() {
        // Regression guard for why signatures must not ride Json::Num:
        // numbers are f64 (53-bit mantissa), so 54-bit integers corrupt.
        let big = (1u64 << 53) + 1;
        assert_ne!(Json::Num(big as f64).as_u64(), Some(big));
        // 48-bit node addresses (protocol::identity::ADDRESS_MASK) are
        // exact — the largest one round-trips through print/parse.
        let addr = 0xFFFF_FFFF_FFFFu64;
        let j = Json::from(addr);
        assert_eq!(j.as_u64(), Some(addr));
        assert_eq!(Json::parse(&j.to_string()).unwrap().as_u64(), Some(addr));
        // 32-byte signatures go hex and round-trip losslessly, including
        // through serialization.
        let sig: Vec<u8> = (0..32).map(|i| (i * 37 + 251) as u8).collect();
        let j = Json::hex(&sig);
        assert_eq!(j.as_hex_bytes().unwrap(), sig);
        assert_eq!(Json::parse(&j.to_string()).unwrap().as_hex_bytes().unwrap(), sig);
        // Malformed hex is rejected, not mangled.
        assert_eq!(Json::Str("abc".into()).as_hex_bytes(), None);
        assert_eq!(Json::Str("zz".into()).as_hex_bytes(), None);
        assert_eq!(Json::Num(3.0).as_hex_bytes(), None);
    }
}
