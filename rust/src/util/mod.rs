//! From-scratch substrates: deterministic PRNG, JSON, CLI parsing, logging,
//! metrics, a criterion-style bench harness and a proptest-style property
//! runner. All std-only (the offline crate set has no tokio/serde/clap/...).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod metrics;
pub mod numeric;
pub mod pool;
pub mod prop;
pub mod retry;
pub mod rng;
pub mod wire;

/// Injectable millisecond time source. Production wiring passes
/// [`now_ms`]; tests and the churn harnesses pass a counter they advance
/// by hand, so TTL expiry (discovery records, gossip peer records) is a
/// deterministic function of the schedule instead of a sleep race.
pub type Clock = std::sync::Arc<dyn Fn() -> u64 + Send + Sync>;

/// The default [`Clock`]: monotonic process time.
pub fn real_clock() -> Clock {
    std::sync::Arc::new(now_ms)
}

/// Monotonic milliseconds since process start (cheap wall-clock for logs).
pub fn now_ms() -> u64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Unix time in milliseconds (for ledger timestamps / heartbeat expiry).
pub fn unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}
