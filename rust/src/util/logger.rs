//! Leveled, targeted logging to stderr. `I2_LOG=debug` raises verbosity;
//! `I2_LOG=off` silences (benches do this).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != 255 {
        return cur;
    }
    let lv = match std::env::var("I2_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        Ok("off") => Level::Off,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lv, Ordering::Relaxed);
    lv
}

pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn log(lv: Level, target: &str, msg: &str) {
    if (lv as u8) < level() {
        return;
    }
    let t = crate::util::now_ms();
    let tag = match lv {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
        Level::Off => return,
    };
    eprintln!("[{:>8.3}s {} {}] {}", t as f64 / 1000.0, tag, target, msg);
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, &format!($($arg)*))
    };
}
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, &format!($($arg)*))
    };
}
#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, &format!($($arg)*))
    };
}
#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, $target, &format!($($arg)*))
    };
}
