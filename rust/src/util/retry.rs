//! Shared retry/backoff policy for the swarm's network paths.
//!
//! Every retry loop in the crate used to be ad-hoc: `sleep(10ms)` with a
//! 200-attempt cap in the shardcast client, a 50x20ms poll in the relay
//! puller, and a busy-loop (no sleep at all) on transport errors — which
//! hammers a refused port as fast as `connect()` can fail. [`RetryPolicy`]
//! replaces them with one shape: capped exponential backoff, deterministic
//! jitter drawn from [`crate::util::rng::Rng`] (so chaos runs under the
//! fault plane replay byte-identically), and a total-deadline budget so a
//! dead dependency fails in bounded wall-clock time instead of
//! `attempts x max_delay`.
//!
//! Retries never reach the wire protocol: a retried request is a brand-new
//! HTTP request, so commitments and signed envelopes stay byte-identical
//! whether the first attempt succeeded or the fifth did.

use std::time::{Duration, Instant};

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Maximum number of attempts (>= 1); the first try counts.
    pub max_attempts: u32,
    /// Backoff before the second attempt; doubles every retry after.
    pub base_delay_ms: u64,
    /// Cap on a single backoff sleep.
    pub max_delay_ms: u64,
    /// Fraction of each delay randomized away (0..=1): the actual sleep is
    /// uniform in `[delay * (1 - jitter), delay]`. Jitter decorrelates
    /// clients that failed at the same instant (thundering herd on a relay
    /// that just came back).
    pub jitter: f64,
    /// Total wall-clock budget across all attempts and sleeps in
    /// milliseconds (0 = no budget). Once an upcoming sleep would cross
    /// the budget, the policy gives up instead of sleeping.
    pub total_budget_ms: u64,
}

impl RetryPolicy {
    pub fn new(max_attempts: u32, base_delay_ms: u64, max_delay_ms: u64) -> RetryPolicy {
        RetryPolicy {
            max_attempts,
            base_delay_ms,
            max_delay_ms,
            jitter: 0.5,
            total_budget_ms: 0,
        }
    }

    pub fn with_budget(mut self, total_budget_ms: u64) -> RetryPolicy {
        self.total_budget_ms = total_budget_ms;
        self
    }

    /// Shard downloads: a 503 means the relay is still streaming the shard
    /// in from its parent, so waiting is productive — but back off rather
    /// than hammer (the old loop polled every 10 ms, 200 times).
    pub fn shardcast_shard() -> RetryPolicy {
        RetryPolicy::new(12, 10, 400).with_budget(15_000)
    }

    /// Manifest fetches: cheap requests, failing over across relays; a few
    /// fast attempts beat a long budget (the caller moves to the next
    /// checkpoint on total failure).
    pub fn shardcast_manifest() -> RetryPolicy {
        RetryPolicy::new(6, 20, 300).with_budget(5_000)
    }

    /// Relay pull-from-parent: the puller thread re-runs every poll
    /// interval anyway, so keep individual pulls bounded.
    pub fn relay_pull() -> RetryPolicy {
        RetryPolicy::new(8, 20, 500).with_budget(10_000)
    }

    /// The backoff delay after attempt `attempt` (0-based), jittered.
    pub fn delay_ms(&self, attempt: u32, rng: &mut Rng) -> u64 {
        let exp = attempt.min(16);
        let raw = self
            .base_delay_ms
            .saturating_mul(1u64 << exp)
            .min(self.max_delay_ms.max(self.base_delay_ms));
        let cut = (raw as f64 * self.jitter.clamp(0.0, 1.0) * rng.f64()) as u64;
        raw - cut
    }

    /// Run `op` until it succeeds, attempts run out, or the budget is
    /// spent. `op` receives the 0-based attempt index. The returned error
    /// is the last failure, tagged with `what` and the attempt count.
    pub fn run<T>(
        &self,
        what: &str,
        rng: &mut Rng,
        mut op: impl FnMut(u32) -> anyhow::Result<T>,
    ) -> anyhow::Result<T> {
        let start = Instant::now();
        let attempts = self.max_attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        let mut ran = 0u32;
        for attempt in 0..attempts {
            ran = attempt + 1;
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
            if attempt + 1 == attempts {
                break;
            }
            let delay = self.delay_ms(attempt, rng);
            if self.total_budget_ms > 0 {
                let spent = start.elapsed().as_millis() as u64;
                if spent + delay >= self.total_budget_ms {
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(delay));
        }
        Err(match last {
            Some(e) => anyhow::anyhow!("{what}: gave up after {ran} attempts: {e}"),
            None => anyhow::anyhow!("{what}: no attempts configured"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_are_capped_exponential() {
        let p = RetryPolicy { jitter: 0.0, ..RetryPolicy::new(10, 10, 100) };
        let mut rng = Rng::new(1);
        let delays: Vec<u64> = (0..6).map(|a| p.delay_ms(a, &mut rng)).collect();
        assert_eq!(delays, vec![10, 20, 40, 80, 100, 100]);
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let p = RetryPolicy::new(10, 10, 1000);
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let da: Vec<u64> = (0..8).map(|i| p.delay_ms(i, &mut a)).collect();
        let db: Vec<u64> = (0..8).map(|i| p.delay_ms(i, &mut b)).collect();
        assert_eq!(da, db);
        // Jittered delays stay within [delay/2, delay] for jitter = 0.5.
        for (i, d) in da.iter().enumerate() {
            let raw = (10u64 << i.min(16)).min(1000);
            assert!(*d <= raw && *d >= raw / 2, "attempt {i}: {d} not in [{}, {raw}]", raw / 2);
        }
    }

    #[test]
    fn run_retries_until_success() {
        let p = RetryPolicy { base_delay_ms: 1, max_delay_ms: 2, ..RetryPolicy::new(5, 1, 2) };
        let mut rng = Rng::new(3);
        let mut calls = 0;
        let out: anyhow::Result<u32> = p.run("flaky", &mut rng, |attempt| {
            calls += 1;
            anyhow::ensure!(attempt >= 2, "not yet");
            Ok(attempt)
        });
        assert_eq!(out.unwrap(), 2);
        assert_eq!(calls, 3);
    }

    #[test]
    fn run_reports_last_error_after_exhaustion() {
        let p = RetryPolicy { base_delay_ms: 1, max_delay_ms: 1, ..RetryPolicy::new(3, 1, 1) };
        let mut rng = Rng::new(4);
        let err = p
            .run("doomed", &mut rng, |a| -> anyhow::Result<()> {
                anyhow::bail!("failure #{a}")
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("doomed"), "{err}");
        assert!(err.contains("3 attempts"), "{err}");
        assert!(err.contains("failure #2"), "{err}");
    }

    #[test]
    fn budget_stops_before_attempts_run_out() {
        let p = RetryPolicy {
            max_attempts: 100,
            base_delay_ms: 50,
            max_delay_ms: 50,
            jitter: 0.0,
            total_budget_ms: 120,
        };
        let mut rng = Rng::new(5);
        let t0 = Instant::now();
        let mut calls = 0u32;
        let _ = p.run("budgeted", &mut rng, |_| -> anyhow::Result<()> {
            calls += 1;
            anyhow::bail!("down")
        });
        // 100 attempts x 50ms would be 5s; the budget cuts it to ~120ms.
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(calls < 10, "{calls}");
    }
}
