//! Metrics: lock-striped counters/gauges for the hot path, plus `Series` —
//! step-indexed scalar traces that experiment harnesses dump as JSONL/CSV
//! (every paper figure is regenerated from these).

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// A monotone counter (tokens generated, rollouts verified, bytes sent...).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed) + 1
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge (queue depth, in-flight requests).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Streaming histogram with fixed log-spaced buckets (latencies in micros).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, v: u64) {
        let b = (64 - v.max(1).leading_zeros() as usize).min(63);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << i;
            }
        }
        u64::MAX
    }
}

/// Step-indexed scalar traces: `series.push(step, "task_reward", 0.42)`.
/// One `Series` per run; harnesses write them to `runs/<name>.jsonl`.
#[derive(Default)]
pub struct Series {
    rows: Mutex<Vec<(u64, String, f64)>>,
}

impl Series {
    pub fn push(&self, step: u64, name: &str, value: f64) {
        self.rows.lock().unwrap().push((step, name.to_string(), value));
    }

    pub fn get(&self, name: &str) -> Vec<(u64, f64)> {
        self.rows
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, n, _)| n == name)
            .map(|(s, _, v)| (*s, *v))
            .collect()
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.rows.lock().unwrap().iter().map(|(_, n, _)| n.clone()).collect();
        names.sort();
        names.dedup();
        names
    }

    /// Moving average over the trailing `w` points (paper figures smooth
    /// with a 10-step moving average).
    pub fn smoothed(&self, name: &str, w: usize) -> Vec<(u64, f64)> {
        let xs = self.get(name);
        xs.iter()
            .enumerate()
            .map(|(i, (s, _))| {
                let lo = i.saturating_sub(w.saturating_sub(1));
                let window = &xs[lo..=i];
                let mean = window.iter().map(|(_, v)| v).sum::<f64>() / window.len() as f64;
                (*s, mean)
            })
            .collect()
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for (step, name, value) in self.rows.lock().unwrap().iter() {
            out.push_str(&format!(
                "{{\"step\":{step},\"name\":\"{name}\",\"value\":{value}}}\n"
            ));
        }
        out
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_jsonl().as_bytes())
    }
}

/// Render aligned text columns (experiment harnesses print paper-style
/// tables with this).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |cells: Vec<String>, widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&line(headers.iter().map(|s| s.to_string()).collect(), &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row.clone(), &widths));
        out.push('\n');
    }
    out
}

/// Sparkline for quick terminal plots of a series.
pub fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-12);
    values
        .iter()
        .map(|&v| BARS[(((v - lo) / span) * 7.0).round() as usize])
        .collect()
}

/// Labeled attempt/pass accounting (per-environment task pass rates in
/// mixed-env runs: `SwarmStats::env_pass`, rendered by `util_table`).
#[derive(Default)]
pub struct PassRates {
    inner: Mutex<BTreeMap<String, (u64, u64)>>,
}

impl PassRates {
    pub fn record(&self, key: &str, pass: bool) {
        self.add(key, 1, pass as u64);
    }

    pub fn add(&self, key: &str, attempts: u64, passes: u64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(key.to_string()).or_insert((0, 0));
        e.0 += attempts;
        e.1 += passes;
    }

    /// `(key, attempts, passes)` sorted by key.
    pub fn snapshot(&self) -> Vec<(String, u64, u64)> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &(a, p))| (k.clone(), a, p))
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// Table rows `[key, attempts, pass %]` for [`render_table`].
    pub fn rows(&self) -> Vec<Vec<String>> {
        self.snapshot()
            .into_iter()
            .map(|(k, a, p)| {
                vec![k, a.to_string(), format!("{:.1}%", 100.0 * p as f64 / a.max(1) as f64)]
            })
            .collect()
    }
}

/// Registry bundling the standard run counters, shared across subsystems.
#[derive(Default)]
pub struct Registry {
    pub counters: BTreeMap<&'static str, Counter>,
}

impl Registry {
    pub fn with(names: &[&'static str]) -> Registry {
        let mut r = Registry::default();
        for n in names {
            r.counters.insert(n, Counter::default());
        }
        r
    }

    pub fn counter(&self, name: &str) -> &Counter {
        self.counters.get(name).expect("unregistered counter")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(7);
        g.add(-3);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::default();
        for v in [1u64, 2, 4, 8, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.5) <= 8);
        assert!(h.quantile(1.0) >= 1000);
        assert!((h.mean() - 203.0).abs() < 1.0);
    }

    #[test]
    fn series_smoothing() {
        let s = Series::default();
        for i in 0..10 {
            s.push(i, "x", i as f64);
        }
        let sm = s.smoothed("x", 2);
        assert_eq!(sm[0].1, 0.0);
        assert_eq!(sm[9].1, 8.5);
        assert_eq!(s.get("x").len(), 10);
        assert!(s.to_jsonl().lines().count() == 10);
    }

    #[test]
    fn table_renders() {
        let t = render_table(&["a", "bb"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("a  bb"), "{t}");
    }

    #[test]
    fn pass_rates_accumulate_per_key() {
        let r = PassRates::default();
        assert!(r.is_empty());
        r.record("math", true);
        r.record("math", false);
        r.record("seq", true);
        r.add("code", 4, 1);
        assert_eq!(
            r.snapshot(),
            vec![
                ("code".into(), 4, 1),
                ("math".into(), 2, 1),
                ("seq".into(), 1, 1)
            ]
        );
        let rows = r.rows();
        assert_eq!(rows[1], vec!["math".to_string(), "2".into(), "50.0%".into()]);
    }

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.chars().count(), 4);
    }
}
