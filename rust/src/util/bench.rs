//! Criterion-style bench harness (criterion itself is not in the offline
//! crate set). `cargo bench` targets use `harness = false` and drive this.
//! [`BenchReport`] additionally serializes results + named metrics to
//! `BENCH_<name>.json` so the perf trajectory is tracked across PRs (CI
//! uploads these files as workflow artifacts).

use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let fmt = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        let mut s = format!(
            "{:<44} iters={:<6} mean={:<11} p50={:<11} p95={:<11} min={}",
            self.name,
            self.iters,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.p95_ns),
            fmt(self.min_ns),
        );
        if let Some((v, unit)) = self.throughput {
            s.push_str(&format!("  [{v:.2} {unit}]"));
        }
        s
    }
}

pub struct Bencher {
    pub warmup_iters: u64,
    pub measure_iters: u64,
    pub max_seconds: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, measure_iters: 30, max_seconds: 10.0 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, measure_iters: 10, max_seconds: 5.0 }
    }

    /// Time `f`, printing a criterion-style line. Returns stats for
    /// throughput post-processing.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters as usize);
        let start = Instant::now();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
            if start.elapsed().as_secs_f64() > self.max_seconds && samples.len() >= 5 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let res = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            mean_ns: mean,
            p50_ns: samples[n / 2],
            p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: samples[0],
            throughput: None,
        };
        println!("{}", res.report());
        res
    }

    /// Like `run` but annotates items/second computed from `items` per call.
    pub fn run_throughput<F: FnMut()>(
        &self,
        name: &str,
        items: f64,
        unit: &'static str,
        f: F,
    ) -> BenchResult {
        let mut res = self.run(name, f);
        res.throughput = Some((items / (res.mean_ns / 1e9), unit));
        println!("  -> {:.2} {}/s", items / (res.mean_ns / 1e9), unit);
        res
    }
}

/// Machine-readable bench output: collects [`BenchResult`]s plus named
/// scalar metrics (speedups, items/sec) and writes `BENCH_<name>.json`.
/// Destination directory: `$BENCH_JSON_DIR`, defaulting to the working
/// directory (`rust/` under `cargo bench`).
pub struct BenchReport {
    name: String,
    results: Vec<Json>,
    metrics: Vec<(String, f64)>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), results: Vec::new(), metrics: Vec::new() }
    }

    /// Record a timed result (call with what `Bencher::run` returned).
    pub fn record(&mut self, r: &BenchResult) {
        self.results.push(Json::obj(vec![
            ("name", r.name.as_str().into()),
            ("iters", r.iters.into()),
            ("mean_ns", r.mean_ns.into()),
            ("p50_ns", r.p50_ns.into()),
            ("p95_ns", r.p95_ns.into()),
            ("min_ns", r.min_ns.into()),
            (
                "throughput",
                match r.throughput {
                    Some((v, unit)) => {
                        Json::obj(vec![("value", v.into()), ("unit", unit.into())])
                    }
                    None => Json::Null,
                },
            ),
        ]));
    }

    /// Record a named scalar metric (a speedup, a rollouts/sec figure...).
    pub fn metric(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path written.
    pub fn write_to(&self, dir: &str) -> anyhow::Result<PathBuf> {
        let path = PathBuf::from(dir).join(format!("BENCH_{}.json", self.name));
        let json = Json::obj(vec![
            ("bench", self.name.as_str().into()),
            ("results", Json::Arr(self.results.clone())),
            (
                "metrics",
                Json::Obj(self.metrics.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect()),
            ),
        ]);
        std::fs::write(&path, format!("{json}\n"))?;
        Ok(path)
    }

    /// Write to `$BENCH_JSON_DIR` (default: working directory).
    pub fn write(&self) -> anyhow::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
        self.write_to(&dir)
    }
}

/// One comparable figure from a `BENCH_<name>.json`: a named scalar metric
/// or a result's throughput.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchFigure {
    pub key: String,
    pub value: f64,
    /// Comparison direction — the `metrics` map mixes speedups and
    /// items/sec (higher is better) with waste/padding fractions, call
    /// counts and overhead ratios (lower is better).
    pub lower_is_better: bool,
}

/// Is a smaller value of this metric an improvement? Keyed off the naming
/// conventions the benches actually use: `*_waste`, `*_fraction`/`*_frac`,
/// `*_calls_*`, `*_overhead*`, raw `*_ns` timings, cost ratios spelled
/// `*_per_*` (validator_compute_per_verified_token in
/// `BENCH_toploc.json`), and the generation scheduler's `*_steps` /
/// `*_prompts` work counts (decode_steps, prefill_calls, prefill_prompts
/// in `BENCH_generation.json`) shrink when things get better;
/// throughputs, speedups, occupancies and gains grow. One carve-out: a
/// `*_per_s`/`*_per_sec` suffix is a throughput (rollouts_per_s_*), not a
/// cost ratio, despite carrying the `_per_` marker. Serving latencies
/// (`*_ms` wall milliseconds and the `*_p50`/`*_p99` percentile figures
/// in `BENCH_serving.json`) shrink when serving gets better, and so do
/// byte counts (`origin_egress_bytes` in `BENCH_shardcast.json` — egress
/// the swarm pays for).
fn lower_is_better(key: &str) -> bool {
    if key.contains("_per_s") {
        return false;
    }
    [
        "_waste", "_fraction", "_frac", "_calls", "_overhead", "_ns", "_steps", "_prompts",
        "_per_", "_ms", "_p50", "_p99", "_bytes",
    ]
    .iter()
    .any(|marker| key.contains(marker))
}

/// Old-vs-new delta for one figure; `delta_frac` is `(new - old) / old`,
/// so `-0.2` means the figure dropped 20%.
#[derive(Clone, Debug)]
pub struct FigureDelta {
    pub key: String,
    pub old: f64,
    pub new: f64,
    pub delta_frac: f64,
    pub lower_is_better: bool,
}

impl FigureDelta {
    /// Did this figure move in its bad direction by more than
    /// `threshold_frac`?
    pub fn regressed(&self, threshold_frac: f64) -> bool {
        if self.lower_is_better {
            self.delta_frac > threshold_frac.abs()
        } else {
            self.delta_frac < -threshold_frac.abs()
        }
    }
}

/// Extract the comparable figures from a parsed `BENCH_<name>.json`.
pub fn bench_figures(doc: &Json) -> Vec<BenchFigure> {
    let mut out = Vec::new();
    if let Some(metrics) = doc.get("metrics").and_then(Json::as_obj) {
        for (k, v) in metrics {
            if let Some(value) = v.as_f64() {
                out.push(BenchFigure {
                    key: k.clone(),
                    value,
                    lower_is_better: lower_is_better(k),
                });
            }
        }
    }
    for r in doc.get("results").and_then(Json::as_arr).unwrap_or(&[]) {
        let (Some(name), Some(value)) = (
            r.get("name").and_then(Json::as_str),
            r.path(&["throughput", "value"]).and_then(Json::as_f64),
        ) else {
            continue;
        };
        out.push(BenchFigure {
            key: format!("throughput:{name}"),
            value,
            lower_is_better: false,
        });
    }
    out
}

/// Compare two bench documents figure by figure (keys present in both).
/// The bench-regression CI step feeds this the previous run's artifact
/// and the current run's output and warns on moves past its threshold in
/// each figure's bad direction.
pub fn compare_bench_docs(old: &Json, new: &Json) -> Vec<FigureDelta> {
    let new_figs: Vec<BenchFigure> = bench_figures(new);
    bench_figures(old)
        .into_iter()
        .filter_map(|o| {
            let n = new_figs.iter().find(|f| f.key == o.key)?;
            if o.value == 0.0 {
                return None;
            }
            Some(FigureDelta {
                key: o.key,
                old: o.value,
                new: n.value,
                delta_frac: (n.value - o.value) / o.value,
                lower_is_better: o.lower_is_better,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let b = Bencher { warmup_iters: 1, measure_iters: 5, max_seconds: 2.0 };
        let r = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns >= r.min_ns);
    }

    #[test]
    fn report_emits_json() {
        let b = Bencher { warmup_iters: 0, measure_iters: 2, max_seconds: 1.0 };
        let mut rep = BenchReport::new("selftest");
        let r = b.run_throughput("noop", 10.0, "items", || {
            std::hint::black_box(1 + 1);
        });
        rep.record(&r);
        rep.metric("speedup", 2.5);
        let dir = std::env::temp_dir();
        let path = rep.write_to(dir.to_str().unwrap()).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap() == "BENCH_selftest.json");
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("selftest"));
        assert_eq!(
            parsed.path(&["metrics", "speedup"]).and_then(Json::as_f64),
            Some(2.5)
        );
        let first = parsed.get("results").and_then(|r| r.idx(0)).unwrap();
        assert_eq!(first.get("name").and_then(Json::as_str), Some("noop"));
        assert!(first.path(&["throughput", "value"]).and_then(Json::as_f64).unwrap() > 0.0);
        let _ = std::fs::remove_file(path);
    }

    fn doc(speedup: f64, waste: f64, thr: f64) -> Json {
        Json::parse(&format!(
            r#"{{"bench":"x","metrics":{{"speedup":{speedup},"packed_waste":{waste}}},"results":[
                {{"name":"verify","throughput":{{"value":{thr},"unit":"rollouts"}}}},
                {{"name":"no-thr","throughput":null}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn comparator_flags_regressions_only() {
        let deltas = compare_bench_docs(&doc(2.0, 0.30, 100.0), &doc(2.2, 0.10, 70.0));
        assert_eq!(deltas.len(), 3);
        let speedup = deltas.iter().find(|d| d.key == "speedup").unwrap();
        assert!((speedup.delta_frac - 0.1).abs() < 1e-9);
        assert!(!speedup.regressed(0.15));
        let thr = deltas.iter().find(|d| d.key == "throughput:verify").unwrap();
        assert!((thr.delta_frac + 0.3).abs() < 1e-9);
        assert!(thr.regressed(0.15));
        assert!(!thr.regressed(0.5));
        // Lower-is-better figures invert: padding waste dropping 67% is an
        // improvement, not a regression...
        let waste = deltas.iter().find(|d| d.key == "packed_waste").unwrap();
        assert!(waste.lower_is_better);
        assert!(waste.delta_frac < 0.0);
        assert!(!waste.regressed(0.15));
        // ...and waste *rising* is one.
        let worse = compare_bench_docs(&doc(2.0, 0.10, 100.0), &doc(2.0, 0.30, 100.0));
        assert!(worse.iter().find(|d| d.key == "packed_waste").unwrap().regressed(0.15));
        // Figures missing on either side (or zero baselines) are skipped,
        // not treated as regressions.
        let empty = Json::parse(r#"{"bench":"x","metrics":{},"results":[]}"#).unwrap();
        assert!(compare_bench_docs(&empty, &doc(2.0, 0.1, 100.0)).is_empty());
        assert!(compare_bench_docs(&doc(0.0, 0.0, 0.0), &doc(2.0, 0.1, 100.0)).is_empty());
    }

    #[test]
    fn generation_figures_have_directions() {
        // BENCH_generation.json figures: work counts shrink when the
        // scheduler improves, throughput/occupancy/speedup grow.
        let work = ["decode_steps_continuous", "prefill_calls", "prefill_prompts"];
        for key in work {
            assert!(lower_is_better(key), "{key}");
        }
        for key in ["refill_speedup", "continuous_occupancy", "rollouts_per_s_continuous"] {
            assert!(!lower_is_better(key), "{key}");
        }
    }

    #[test]
    fn sampled_validation_figures_have_directions() {
        // BENCH_toploc.json's sampling figures: compute spent per admitted
        // token shrinks as sampling bites (a `_per_` cost ratio), while
        // the sampled-mode speedup grows — and the `_per_s*` throughput
        // carve-out must keep rollouts/sec figures higher-is-better.
        assert!(lower_is_better("validator_compute_per_verified_token"));
        assert!(!lower_is_better("sampled_speedup"));
        assert!(!lower_is_better("verify_rollouts_per_sec"));
        assert!(!lower_is_better("rollouts_per_s_continuous"));
    }

    #[test]
    fn serving_figures_have_directions() {
        // BENCH_serving.json figures: latency percentiles shrink when
        // serving improves; tokens/sec and goodput retention grow. The
        // `_per_s` carve-out must survive the `_ms`/`_p50`/`_p99` markers.
        for key in ["ttft_p50_ms", "ttft_p99_ms", "serve_wall_ms"] {
            assert!(lower_is_better(key), "{key}");
        }
        for key in ["served_tokens_per_s", "rl_goodput_retention", "queries_served"] {
            assert!(!lower_is_better(key), "{key}");
        }
    }

    #[test]
    fn shardcast_figures_have_directions() {
        // BENCH_shardcast.json figures: the origin's egress and the tree's
        // re-formation latency shrink when the broadcast layer improves;
        // the delta savings fraction and delivery rate grow. `bytes_per_s`
        // style throughputs must survive the new `_bytes` marker.
        for key in ["origin_egress_bytes", "reform_latency_steps"] {
            assert!(lower_is_better(key), "{key}");
        }
        for key in ["delta_egress_savings", "delivery_rate", "shard_bytes_per_s"] {
            assert!(!lower_is_better(key), "{key}");
        }
    }
}
