//! Criterion-style bench harness (criterion itself is not in the offline
//! crate set). `cargo bench` targets use `harness = false` and drive this.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let fmt = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        let mut s = format!(
            "{:<44} iters={:<6} mean={:<11} p50={:<11} p95={:<11} min={}",
            self.name,
            self.iters,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.p95_ns),
            fmt(self.min_ns),
        );
        if let Some((v, unit)) = self.throughput {
            s.push_str(&format!("  [{v:.2} {unit}]"));
        }
        s
    }
}

pub struct Bencher {
    pub warmup_iters: u64,
    pub measure_iters: u64,
    pub max_seconds: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, measure_iters: 30, max_seconds: 10.0 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, measure_iters: 10, max_seconds: 5.0 }
    }

    /// Time `f`, printing a criterion-style line. Returns stats for
    /// throughput post-processing.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters as usize);
        let start = Instant::now();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
            if start.elapsed().as_secs_f64() > self.max_seconds && samples.len() >= 5 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let res = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            mean_ns: mean,
            p50_ns: samples[n / 2],
            p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: samples[0],
            throughput: None,
        };
        println!("{}", res.report());
        res
    }

    /// Like `run` but annotates items/second computed from `items` per call.
    pub fn run_throughput<F: FnMut()>(
        &self,
        name: &str,
        items: f64,
        unit: &'static str,
        f: F,
    ) -> BenchResult {
        let mut res = self.run(name, f);
        res.throughput = Some((items / (res.mean_ns / 1e9), unit));
        println!("  -> {:.2} {}/s", items / (res.mean_ns / 1e9), unit);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let b = Bencher { warmup_iters: 1, measure_iters: 5, max_seconds: 2.0 };
        let r = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns >= r.min_ns);
    }
}
