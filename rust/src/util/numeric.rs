//! Canonical float accumulation for commitment / verdict code.
//!
//! Float addition is not associative, so any sum whose *order* is
//! unspecified (or tied to iteration order of an unordered container) can
//! differ between the worker that produced a value and the validator that
//! recomputes it — enough to flip a tolerance check and make a slashing
//! verdict irreproducible. Trust-critical code must fold floats through
//! these helpers (swarmlint rule `float-fold`): a documented left-to-right
//! fold over an explicitly ordered iterator, identical on every host.

/// Left-to-right sum of `f64` terms, in exactly the order yielded.
pub fn fold_f64<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    xs.into_iter().fold(0.0, |acc, x| acc + x)
}

/// Left-to-right sum of `f32` terms, in exactly the order yielded.
pub fn fold_f32<I: IntoIterator<Item = f32>>(xs: I) -> f32 {
    xs.into_iter().fold(0.0, |acc, x| acc + x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_sequential_addition() {
        let xs = [1.0e16, 1.0, -1.0e16, 1.0];
        let mut acc = 0.0;
        for x in xs {
            acc += x;
        }
        assert_eq!(fold_f64(xs), acc);
    }

    #[test]
    fn order_sensitivity_is_why_this_exists() {
        // The same multiset of terms, two orders, two answers: exactly the
        // hazard the canonical fold pins down.
        let a = fold_f64([1.0e16, 1.0, -1.0e16]);
        let b = fold_f64([1.0e16, -1.0e16, 1.0]);
        assert_ne!(a, b);
    }

    #[test]
    fn f32_fold_left_to_right() {
        let xs = [0.1f32, 0.2, 0.3];
        assert_eq!(fold_f32(xs), ((0.0 + 0.1) + 0.2) + 0.3);
    }
}
