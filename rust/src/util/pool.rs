//! Minimal thread pool (no rayon/tokio offline). Owns N workers pulling
//! boxed jobs from a shared queue; `scope`-style join via completion count
//! under a condvar (waiters sleep until the last job signals, instead of
//! the 200 µs spin-poll this replaces).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// (submitted, completed) job counts, guarded together so `wait_idle`'s
/// check-then-wait can't lose a wakeup.
struct Counts {
    counts: Mutex<(u64, u64)>,
    idle: Condvar,
}

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    state: Arc<Counts>,
    shutdown: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let state = Arc::new(Counts { counts: Mutex::new((0, 0)), idle: Condvar::new() });
        let shutdown = Arc::new(AtomicBool::new(false));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let state = Arc::clone(&state);
                std::thread::Builder::new()
                    .name(format!("i2-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                // Panic firewall: a panicking job must not
                                // kill the worker (stranding queued jobs)
                                // or skip the completion tick (deadlocking
                                // wait_idle forever). It counts as
                                // completed; its result slot stays empty
                                // for the submitter to handle.
                                let panicked = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                )
                                .is_err();
                                if panicked {
                                    crate::warn!("pool", "job panicked (counted as completed)");
                                }
                                // The job (and everything it captured) is
                                // dropped before the count ticks, so a
                                // woken waiter observes fully-released jobs.
                                let mut c = state.counts.lock().unwrap();
                                c.1 += 1;
                                if c.1 == c.0 {
                                    state.idle.notify_all();
                                }
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, state, shutdown }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.state.counts.lock().unwrap().0 += 1;
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has completed (condvar-woken by the
    /// job that drains the queue).
    pub fn wait_idle(&self) {
        let mut c = self.state.counts.lock().unwrap();
        while c.1 < c.0 {
            c = self.state.idle.wait(c).unwrap();
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run a batch of closures across a temporary pool and collect results in
/// input order (fork-join helper used by validators / workload generators).
pub fn map_parallel<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    {
        let pool = ThreadPool::new(threads);
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            pool.submit(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        pool.wait_idle();
    }
    Arc::try_unwrap(results)
        .ok()
        .expect("pool drained")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_parallel_preserves_order() {
        let out = map_parallel((0..50).collect::<Vec<u64>>(), 4, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn panicking_job_does_not_deadlock_wait_idle() {
        // The validation pipeline blocks in wait_idle every wave over
        // attacker-controlled inputs: a panicking job must count as
        // completed and leave the worker alive for the jobs behind it.
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("hostile input"));
        for _ in 0..3 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // must return despite the panic
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn wait_idle_across_waves() {
        // No jobs: returns immediately. Then several submit/wait waves on
        // the same pool (the validation pipeline's usage pattern).
        let pool = ThreadPool::new(3);
        pool.wait_idle();
        let counter = Arc::new(AtomicU64::new(0));
        for wave in 1..=4u64 {
            for _ in 0..25 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::SeqCst), wave * 25);
        }
    }
}
