//! Minimal thread pool (no rayon/tokio offline). Owns N workers pulling
//! boxed jobs from a shared queue; `scope`-style join via completion count.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    submitted: Arc<AtomicU64>,
    completed: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let submitted = Arc::new(AtomicU64::new(0));
        let completed = Arc::new(AtomicU64::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let completed = Arc::clone(&completed);
                std::thread::Builder::new()
                    .name(format!("i2-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                completed.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, submitted, completed, shutdown }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submitted.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        while self.completed.load(Ordering::SeqCst) < self.submitted.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run a batch of closures across a temporary pool and collect results in
/// input order (fork-join helper used by validators / workload generators).
pub fn map_parallel<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    {
        let pool = ThreadPool::new(threads);
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            pool.submit(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        pool.wait_idle();
    }
    Arc::try_unwrap(results)
        .ok()
        .expect("pool drained")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_parallel_preserves_order() {
        let out = map_parallel((0..50).collect::<Vec<u64>>(), 4, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }
}
