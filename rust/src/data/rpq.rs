//! `rpq` — a from-scratch columnar rollout-file format.
//!
//! Plays the role Parquet plays in the paper: inference workers serialize
//! rollout batches to a typed columnar file, upload it, and the trainer's
//! dataloader reads it back. The validator's "parquet formatting check"
//! (§2.3.3) maps to [`RpqFile::validate_schema`]: a file that does not
//! parse, fails its checksums, or deviates from the expected schema is
//! rejected before it can throw inside the trainer.
//!
//! Layout (little-endian):
//!   magic "RPQ1" | u32 n_cols | u32 n_rows
//!   per column: u16 name_len | name | u8 dtype | u64 data_len | data
//!               | 32-byte SHA-256 of data
//!   footer: 32-byte SHA-256 over everything before it

use sha2::{Digest, Sha256};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    U64 = 0,
    F32 = 1,
    I32List = 2,
    F32List = 3,
    Bytes = 4,
}

impl DType {
    fn from_u8(v: u8) -> Option<DType> {
        Some(match v {
            0 => DType::U64,
            1 => DType::F32,
            2 => DType::I32List,
            3 => DType::F32List,
            4 => DType::Bytes,
            _ => return None,
        })
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Column {
    U64(Vec<u64>),
    F32(Vec<f32>),
    I32List(Vec<Vec<i32>>),
    F32List(Vec<Vec<f32>>),
    Bytes(Vec<Vec<u8>>),
}

impl Column {
    pub fn dtype(&self) -> DType {
        match self {
            Column::U64(_) => DType::U64,
            Column::F32(_) => DType::F32,
            Column::I32List(_) => DType::I32List,
            Column::F32List(_) => DType::F32List,
            Column::Bytes(_) => DType::Bytes,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Column::U64(v) => v.len(),
            Column::F32(v) => v.len(),
            Column::I32List(v) => v.len(),
            Column::F32List(v) => v.len(),
            Column::Bytes(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_u64(&self) -> Option<&[u64]> {
        match self {
            Column::U64(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Column::F32(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_i32_list(&self) -> Option<&[Vec<i32>]> {
        match self {
            Column::I32List(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_f32_list(&self) -> Option<&[Vec<f32>]> {
        match self {
            Column::F32List(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_bytes(&self) -> Option<&[Vec<u8>]> {
        match self {
            Column::Bytes(v) => Some(v),
            _ => None,
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Column::U64(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Column::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Column::I32List(v) => {
                encode_offsets(v.iter().map(|x| x.len()), &mut out);
                for row in v {
                    for x in row {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
            Column::F32List(v) => {
                encode_offsets(v.iter().map(|x| x.len()), &mut out);
                for row in v {
                    for x in row {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
            Column::Bytes(v) => {
                encode_offsets(v.iter().map(|x| x.len()), &mut out);
                for row in v {
                    out.extend_from_slice(row);
                }
            }
        }
        out
    }

    fn decode(dtype: DType, n_rows: usize, data: &[u8]) -> anyhow::Result<Column> {
        Ok(match dtype {
            DType::U64 => {
                anyhow::ensure!(data.len() == n_rows * 8, "u64 column size");
                Column::U64(data.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
            }
            DType::F32 => {
                anyhow::ensure!(data.len() == n_rows * 4, "f32 column size");
                Column::F32(data.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
            }
            DType::I32List => {
                let (lens, rest) = decode_offsets(n_rows, data)?;
                let total: usize = lens.iter().sum();
                anyhow::ensure!(rest.len() == total * 4, "i32list column size");
                let mut vals = rest.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap()));
                Column::I32List(lens.iter().map(|&l| (0..l).map(|_| vals.next().unwrap()).collect()).collect())
            }
            DType::F32List => {
                let (lens, rest) = decode_offsets(n_rows, data)?;
                let total: usize = lens.iter().sum();
                anyhow::ensure!(rest.len() == total * 4, "f32list column size");
                let mut vals = rest.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap()));
                Column::F32List(lens.iter().map(|&l| (0..l).map(|_| vals.next().unwrap()).collect()).collect())
            }
            DType::Bytes => {
                let (lens, rest) = decode_offsets(n_rows, data)?;
                let total: usize = lens.iter().sum();
                anyhow::ensure!(rest.len() == total, "bytes column size");
                let mut pos = 0;
                Column::Bytes(
                    lens.iter()
                        .map(|&l| {
                            let row = rest[pos..pos + l].to_vec();
                            pos += l;
                            row
                        })
                        .collect(),
                )
            }
        })
    }
}

fn encode_offsets(lens: impl Iterator<Item = usize>, out: &mut Vec<u8>) {
    for l in lens {
        out.extend_from_slice(&(l as u32).to_le_bytes());
    }
}

fn decode_offsets(n_rows: usize, data: &[u8]) -> anyhow::Result<(Vec<usize>, &[u8])> {
    anyhow::ensure!(data.len() >= n_rows * 4, "offsets truncated");
    let lens = data[..n_rows * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect();
    Ok((lens, &data[n_rows * 4..]))
}

#[derive(Clone, Debug, Default)]
pub struct RpqFile {
    pub columns: Vec<(String, Column)>,
}

pub type Schema = Vec<(&'static str, DType)>;

impl RpqFile {
    pub fn new() -> RpqFile {
        RpqFile::default()
    }

    pub fn push(&mut self, name: &str, col: Column) -> &mut Self {
        self.columns.push((name.to_string(), col));
        self
    }

    pub fn n_rows(&self) -> usize {
        self.columns.first().map(|(_, c)| c.len()).unwrap_or(0)
    }

    pub fn col(&self, name: &str) -> Option<&Column> {
        self.columns.iter().find(|(n, _)| n == name).map(|(_, c)| c)
    }

    /// The validator's "formatting check": schema (names, dtypes, order)
    /// must match exactly and all columns must have the same row count.
    pub fn validate_schema(&self, schema: &Schema) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.columns.len() == schema.len(),
            "column count {} != {}",
            self.columns.len(),
            schema.len()
        );
        let n = self.n_rows();
        for ((name, col), (want_name, want_dt)) in self.columns.iter().zip(schema) {
            anyhow::ensure!(name == want_name, "column name {name:?} != {want_name:?}");
            anyhow::ensure!(col.dtype() == *want_dt, "column {name}: dtype mismatch");
            anyhow::ensure!(col.len() == n, "column {name}: ragged row count");
        }
        Ok(())
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"RPQ1");
        out.extend_from_slice(&(self.columns.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.n_rows() as u32).to_le_bytes());
        for (name, col) in &self.columns {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(col.dtype() as u8);
            let data = col.encode();
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            let digest = Sha256::digest(&data);
            out.extend_from_slice(&data);
            out.extend_from_slice(&digest);
        }
        let footer = Sha256::digest(&out);
        out.extend_from_slice(&footer);
        out
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<RpqFile> {
        anyhow::ensure!(bytes.len() >= 44, "file truncated");
        let (body, footer) = bytes.split_at(bytes.len() - 32);
        anyhow::ensure!(
            Sha256::digest(body).as_slice() == footer,
            "file checksum mismatch"
        );
        anyhow::ensure!(&body[..4] == b"RPQ1", "bad magic");
        let n_cols = u32::from_le_bytes(body[4..8].try_into().unwrap()) as usize;
        let n_rows = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
        let mut pos = 12;
        let mut columns = Vec::with_capacity(n_cols);
        for _ in 0..n_cols {
            anyhow::ensure!(pos + 2 <= body.len(), "truncated column header");
            let name_len = u16::from_le_bytes(body[pos..pos + 2].try_into().unwrap()) as usize;
            pos += 2;
            anyhow::ensure!(pos + name_len + 9 <= body.len(), "truncated column header");
            let name = String::from_utf8(body[pos..pos + name_len].to_vec())?;
            pos += name_len;
            let dtype = DType::from_u8(body[pos]).ok_or_else(|| anyhow::anyhow!("bad dtype"))?;
            pos += 1;
            let data_len = u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap()) as usize;
            pos += 8;
            anyhow::ensure!(pos + data_len + 32 <= body.len(), "truncated column data");
            let data = &body[pos..pos + data_len];
            pos += data_len;
            let digest = &body[pos..pos + 32];
            pos += 32;
            anyhow::ensure!(
                Sha256::digest(data).as_slice() == digest,
                "column {name}: checksum mismatch"
            );
            columns.push((name, Column::decode(dtype, n_rows, data)?));
        }
        anyhow::ensure!(pos == body.len(), "trailing bytes");
        Ok(RpqFile { columns })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn sample_file() -> RpqFile {
        let mut f = RpqFile::new();
        f.push("task_id", Column::U64(vec![1, 2, 3]))
            .push("reward", Column::F32(vec![1.0, 0.0, 1.0]))
            .push("tokens", Column::I32List(vec![vec![1, 5, 2], vec![], vec![9]]))
            .push("probs", Column::F32List(vec![vec![0.5], vec![0.1, 0.9], vec![]]))
            .push("commit", Column::Bytes(vec![b"abc".to_vec(), vec![], b"zz".to_vec()]));
        f
    }

    #[test]
    fn roundtrip() {
        let f = sample_file();
        let bytes = f.encode();
        let g = RpqFile::decode(&bytes).unwrap();
        assert_eq!(f.columns, g.columns);
        assert_eq!(g.n_rows(), 3);
        assert_eq!(g.col("reward").unwrap().as_f32().unwrap()[2], 1.0);
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = sample_file().encode();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(RpqFile::decode(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample_file().encode();
        for cut in [0, 10, bytes.len() - 1] {
            assert!(RpqFile::decode(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn schema_validation() {
        let f = sample_file();
        let good: Schema = vec![
            ("task_id", DType::U64),
            ("reward", DType::F32),
            ("tokens", DType::I32List),
            ("probs", DType::F32List),
            ("commit", DType::Bytes),
        ];
        f.validate_schema(&good).unwrap();
        let wrong_order: Schema = {
            let mut s = good.clone();
            s.swap(0, 1);
            s
        };
        assert!(f.validate_schema(&wrong_order).is_err());
        let wrong_type: Schema = {
            let mut s = good.clone();
            s[1].1 = DType::U64;
            s
        };
        assert!(f.validate_schema(&wrong_type).is_err());
        assert!(f.validate_schema(&good[..4].to_vec()).is_err());
    }

    #[test]
    fn prop_roundtrip_random_files() {
        prop::check("rpq roundtrip", 48, |rng: &mut Rng, size| {
            let rows = rng.usize(size as usize + 1);
            let mut f = RpqFile::new();
            f.push("ids", Column::U64((0..rows).map(|_| rng.next_u64()).collect()));
            f.push(
                "lists",
                Column::I32List(
                    (0..rows)
                        .map(|_| (0..rng.usize(8)).map(|_| rng.next_u32() as i32).collect())
                        .collect(),
                ),
            );
            f.push(
                "blobs",
                Column::Bytes(
                    (0..rows)
                        .map(|_| (0..rng.usize(16)).map(|_| rng.next_u32() as u8).collect())
                        .collect(),
                ),
            );
            f.encode()
        }, |bytes| {
            let f = RpqFile::decode(bytes).map_err(|e| e.to_string())?;
            prop::ensure_eq(f.encode(), bytes.clone(), "re-encode identical")
        });
    }
}
