//! Data plane substrates: the tokenizer (authoritative vocab shared with
//! the L2 model via vocab size), and `rpq`, the from-scratch columnar
//! rollout-file format standing in for Parquet (§2.1.1, §2.3.3).

pub mod rpq;
pub mod tokenizer;
