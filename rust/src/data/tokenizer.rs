//! Character-level tokenizer over the fixed 64-symbol vocabulary baked into
//! the L2 model artifacts. The Rust side is authoritative: Python only ever
//! sees token ids (`compile/config.py` pins `VOCAB_SIZE`/special ids).

pub const VOCAB_SIZE: usize = 64;
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// Filler token the model emits to pad its "thinking" to a target length
/// (length-reward experiments, §3.1.2).
pub const FILL: i32 = 55;

const PUNCT: &[(u8, i32)] = &[
    (b'+', 13),
    (b'-', 14),
    (b'*', 15),
    (b'=', 16),
    (b'(', 17),
    (b')', 18),
    (b' ', 19),
    (b'?', 20),
    (b':', 21),
    (b',', 22),
    (b'[', 23),
    (b']', 24),
    (b'|', 25),
    (b'#', 26),
    (b'>', 27),
    (b'.', 28),
    (b'~', 55),
    (b'<', 56),
    (b'_', 57),
];

/// char -> token id (digits 3..=12, letters 29..=54, punctuation above).
pub fn encode_char(c: u8) -> i32 {
    match c {
        b'0'..=b'9' => 3 + (c - b'0') as i32,
        b'a'..=b'z' => 29 + (c - b'a') as i32,
        _ => PUNCT.iter().find(|(p, _)| *p == c).map(|(_, id)| *id).unwrap_or(20),
    }
}

pub fn decode_char(id: i32) -> char {
    match id {
        PAD => '∅',
        BOS => '^',
        EOS => '$',
        3..=12 => (b'0' + (id - 3) as u8) as char,
        29..=54 => (b'a' + (id - 29) as u8) as char,
        _ => PUNCT
            .iter()
            .find(|(_, i)| *i == id)
            .map(|(p, _)| *p as char)
            .unwrap_or('?'),
    }
}

pub fn encode(s: &str) -> Vec<i32> {
    s.bytes().map(encode_char).collect()
}

/// Encode with BOS prefix (prompt convention used by the rollout workers).
pub fn encode_prompt(s: &str) -> Vec<i32> {
    let mut out = vec![BOS];
    out.extend(encode(s));
    out
}

pub fn decode(ids: &[i32]) -> String {
    ids.iter().map(|&i| decode_char(i)).collect()
}

/// Decode, stopping at EOS and skipping PAD/BOS (what verifiers see).
pub fn decode_clean(ids: &[i32]) -> String {
    let mut out = String::new();
    for &id in ids {
        if id == EOS {
            break;
        }
        if id == PAD || id == BOS {
            continue;
        }
        out.push(decode_char(id));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_ascii() {
        let s = "12+34=46 sort([3,1,2])>123.";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn ids_in_vocab() {
        for c in 0u8..=255 {
            let id = encode_char(c);
            assert!((0..VOCAB_SIZE as i32).contains(&id), "{c} -> {id}");
        }
    }

    #[test]
    fn specials_distinct() {
        let mut ids: Vec<i32> = (b'a'..=b'z').map(encode_char).collect();
        ids.extend((b'0'..=b'9').map(encode_char));
        ids.extend(PUNCT.iter().map(|(_, i)| *i));
        ids.push(PAD);
        ids.push(BOS);
        ids.push(EOS);
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "vocabulary collision");
    }

    #[test]
    fn decode_clean_stops_at_eos() {
        let ids = vec![BOS, encode_char(b'4'), encode_char(b'2'), EOS, encode_char(b'9')];
        assert_eq!(decode_clean(&ids), "42");
    }

    #[test]
    fn prop_roundtrip_random_strings() {
        prop::check("tokenizer roundtrip", 64, |rng, size| {
            let alphabet = "abcdefghijklmnopqrstuvwxyz0123456789+-*=() ?:,[]|#>.~<_";
            (0..size)
                .map(|_| alphabet.as_bytes()[rng.usize(alphabet.len())] as char)
                .collect::<String>()
        }, |s| {
            prop::ensure_eq(decode(&encode(s)), s.clone(), "roundtrip")
        });
    }
}
