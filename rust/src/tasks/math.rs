//! `MathEnv` ("math"): symbolic arithmetic — the NuminaMath/Deepscaler
//! stand-in, packaged as one [`Environment`] plugin.
//!
//! Difficulty ladder (paper §3.3: dataset difficulty drives RL progress):
//!   0: single-digit addition            "3+4=?"
//!   1: two-digit addition               "27+58=?"
//!   2: subtraction (may go negative)    "31-76=?"
//!   3: single x double digit product    "7*64=?"
//!   4: two-op expression, precedence    "5+3*12=?"
//!   5: parenthesized expression         "(14-6)*7=?"
//!
//! Payload: `{"answer": "<integer>"}` — verification is symbolic (the
//! prompt expression is re-evaluated independently), the stored answer is
//! only the fallback for unparseable prompts.

use super::Task;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::verifier::Environment;

pub const MAX_DIFFICULTY: u8 = 5;

/// The "math" environment plugin.
pub struct MathEnv;

impl Environment for MathEnv {
    fn name(&self) -> &'static str {
        "math"
    }
    fn description(&self) -> &'static str {
        "symbolic arithmetic (NuminaMath/Deepscaler analogue)"
    }
    fn max_difficulty(&self) -> u8 {
        MAX_DIFFICULTY
    }
    fn generate(&self, id: u64, difficulty: u8, rng: &mut Rng) -> Task {
        generate(id, difficulty, rng)
    }
    fn verify(&self, task: &Task, completion: &str) -> bool {
        verify(task, completion)
    }
}

pub fn generate(id: u64, difficulty: u8, rng: &mut Rng) -> Task {
    let (prompt, value) = match difficulty {
        0 => {
            let a = rng.range(0, 10) as i64;
            let b = rng.range(0, 10) as i64;
            (format!("{a}+{b}=?"), a + b)
        }
        1 => {
            let a = rng.range(10, 100) as i64;
            let b = rng.range(10, 100) as i64;
            (format!("{a}+{b}=?"), a + b)
        }
        2 => {
            let a = rng.range(10, 100) as i64;
            let b = rng.range(10, 100) as i64;
            (format!("{a}-{b}=?"), a - b)
        }
        3 => {
            let a = rng.range(2, 10) as i64;
            let b = rng.range(10, 100) as i64;
            (format!("{a}*{b}=?"), a * b)
        }
        4 => {
            let a = rng.range(2, 20) as i64;
            let b = rng.range(2, 10) as i64;
            let c = rng.range(2, 20) as i64;
            (format!("{a}+{b}*{c}=?"), a + b * c)
        }
        _ => {
            let a = rng.range(2, 30) as i64;
            let b = rng.range(2, 30) as i64;
            let c = rng.range(2, 10) as i64;
            if rng.bool(0.5) {
                (format!("({a}-{b})*{c}=?"), (a - b) * c)
            } else {
                (format!("({a}+{b})*{c}=?"), (a + b) * c)
            }
        }
    };
    Task {
        id,
        env: "math",
        prompt,
        difficulty,
        payload: Json::obj(vec![("answer", value.to_string().into())]),
    }
}

/// Symbolic verification: evaluate the prompt expression independently and
/// compare against the parsed numeric answer (not just string match), so
/// "046" or "+46" also count — the paper's "symbolic verifiers".
pub fn verify(task: &Task, completion: &str) -> bool {
    let got = extract_answer(completion);
    match (got, eval_expr(task.prompt.trim_end_matches("=?"))) {
        (Some(g), Some(want)) => g == want,
        (Some(g), None) => task.answer().parse::<i64>().map(|w| w == g).unwrap_or(false),
        _ => false,
    }
}

/// Pull the final integer out of a completion (filler `~`, whitespace and a
/// `>` answer marker are tolerated).
pub fn extract_answer(completion: &str) -> Option<i64> {
    let cleaned: String = completion
        .chars()
        .filter(|c| !matches!(c, '~' | ' '))
        .collect();
    let tail = cleaned.rsplit('>').next().unwrap_or(&cleaned);
    let tail = tail.trim();
    if tail.is_empty() {
        return None;
    }
    let valid = tail.chars().enumerate().all(|(i, c)| {
        c.is_ascii_digit() || (i == 0 && (c == '-' || c == '+'))
    });
    if !valid {
        return None;
    }
    tail.parse::<i64>().ok()
}

/// Tiny recursive-descent evaluator for `+ - * ( )` integer expressions.
pub fn eval_expr(src: &str) -> Option<i64> {
    let bytes: Vec<u8> = src.bytes().filter(|b| *b != b' ').collect();
    let mut pos = 0;
    let v = parse_sum(&bytes, &mut pos)?;
    if pos == bytes.len() {
        Some(v)
    } else {
        None
    }
}

// swarmlint: allow-fn(panic-path) — every b[*pos] below is behind a
// `*pos < b.len()` bound check; the parser is total over hostile bytes.
fn parse_sum(b: &[u8], pos: &mut usize) -> Option<i64> {
    let mut acc = parse_prod(b, pos)?;
    while *pos < b.len() {
        match b[*pos] {
            b'+' => {
                *pos += 1;
                acc = acc.checked_add(parse_prod(b, pos)?)?;
            }
            b'-' => {
                *pos += 1;
                acc = acc.checked_sub(parse_prod(b, pos)?)?;
            }
            _ => break,
        }
    }
    Some(acc)
}

// swarmlint: allow-fn(panic-path) — bounds-guarded indexing, as above.
fn parse_prod(b: &[u8], pos: &mut usize) -> Option<i64> {
    let mut acc = parse_atom(b, pos)?;
    while *pos < b.len() && b[*pos] == b'*' {
        *pos += 1;
        acc = acc.checked_mul(parse_atom(b, pos)?)?;
    }
    Some(acc)
}

// swarmlint: allow-fn(panic-path) — bounds-guarded indexing, as above.
fn parse_atom(b: &[u8], pos: &mut usize) -> Option<i64> {
    if *pos >= b.len() {
        return None;
    }
    if b[*pos] == b'(' {
        *pos += 1;
        let v = parse_sum(b, pos)?;
        if *pos >= b.len() || b[*pos] != b')' {
            return None;
        }
        *pos += 1;
        return Some(v);
    }
    let neg = b[*pos] == b'-';
    if neg {
        *pos += 1;
    }
    let start = *pos;
    while *pos < b.len() && b[*pos].is_ascii_digit() {
        *pos += 1;
    }
    if *pos == start {
        return None;
    }
    let v: i64 = std::str::from_utf8(&b[start..*pos]).ok()?.parse().ok()?;
    Some(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn eval_cases() {
        assert_eq!(eval_expr("2+3*4"), Some(14));
        assert_eq!(eval_expr("(2+3)*4"), Some(20));
        assert_eq!(eval_expr("10-4-3"), Some(3));
        assert_eq!(eval_expr("7"), Some(7));
        assert_eq!(eval_expr("2+*3"), None);
        assert_eq!(eval_expr("(2+3"), None);
        assert_eq!(eval_expr(""), None);
    }

    #[test]
    fn extract_cases() {
        assert_eq!(extract_answer("46"), Some(46));
        assert_eq!(extract_answer("~~~ 46"), Some(46));
        assert_eq!(extract_answer("thinking>-12"), Some(-12));
        assert_eq!(extract_answer("abc"), None);
        assert_eq!(extract_answer("4a6"), None);
        assert_eq!(extract_answer(""), None);
    }

    #[test]
    fn generated_tasks_verify_with_reference_answer() {
        let mut rng = Rng::new(1);
        for d in 0..=MAX_DIFFICULTY {
            for i in 0..50 {
                let t = generate(i, d, &mut rng);
                assert!(verify(&t, t.answer()), "{t:?}");
                assert!(!verify(&t, "999999999"), "{t:?}");
            }
        }
    }

    #[test]
    fn prop_eval_matches_generated_answer() {
        prop::check("math answers consistent", 128, |rng, _| {
            let d = rng.usize(6) as u8;
            generate(0, d, rng)
        }, |t| {
            let expr = t.prompt.trim_end_matches("=?");
            prop::ensure_eq(
                eval_expr(expr),
                t.answer().parse::<i64>().ok(),
                "evaluator vs generator",
            )
        });
    }

    #[test]
    fn verify_accepts_leading_zeros_via_symbolic_eval() {
        let mut rng = Rng::new(3);
        let t = generate(0, 0, &mut rng);
        let padded = format!("0{}", t.answer());
        if !t.answer().starts_with('-') {
            assert!(verify(&t, &padded));
        }
    }
}
