//! `ChainEnv` ("chain"): multi-step arithmetic chains — the second
//! environment added purely through the pluggable registry (one file +
//! one `register` call, like `tasks::seq`).
//!
//! A task is a start value and a chain of operations applied strictly
//! left-to-right — **no precedence**, which is exactly what distinguishes
//! it from the math env: `"7:+3:*2:-4=?"` means `((7+3)*2)-4 = 16`, where
//! the math env's `7+3*2-4` would be `9`. The model must track running
//! state across steps, the multi-step-reasoning axis the paper's
//! length-budget experiments probe.
//!
//! The op list is *hidden verification state*: the verifier refolds the
//! chain from the payload's structured ops, never from the prompt text or
//! the stored answer.
//!
//! Difficulty ladder (number of ops / operand ranges):
//!   0: 2 ops, +/- on small values          "7:+3:-2=?"
//!   1: 3 ops, +/-                          "12:+9:-4:+7=?"
//!   2: 3 ops with *2..*4 mixed in          "5:+3:*2:-4=?"
//!   3: 4 ops, mixed                        "9:*3:-5:+12:*2=?"
//!   4: 5 ops, mixed, larger operands       —
//!
//! Payload: `{"answer": "<result>", "start": s, "ops": [["+",3],["*",2]]}`.

use super::Task;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::verifier::Environment;

pub const MAX_DIFFICULTY: u8 = 4;

/// The "chain" environment plugin.
pub struct ChainEnv;

impl Environment for ChainEnv {
    fn name(&self) -> &'static str {
        "chain"
    }
    fn description(&self) -> &'static str {
        "left-to-right multi-step arithmetic chains (no precedence)"
    }
    fn max_difficulty(&self) -> u8 {
        MAX_DIFFICULTY
    }
    fn generate(&self, id: u64, difficulty: u8, rng: &mut Rng) -> Task {
        generate(id, difficulty, rng)
    }
    fn verify(&self, task: &Task, completion: &str) -> bool {
        verify(task, completion)
    }
}

/// Ops per chain at each difficulty.
pub fn n_ops(difficulty: u8) -> usize {
    match difficulty {
        0 => 2,
        1 | 2 => 3,
        3 => 4,
        _ => 5,
    }
}

/// Fold a chain left-to-right. `None` on an unknown op word (a malformed
/// payload must fail verification, not panic or free-pass).
pub fn fold(start: i64, ops: &[(String, i64)]) -> Option<i64> {
    let mut acc = start;
    for (op, v) in ops {
        acc = match op.as_str() {
            "+" => acc.checked_add(*v)?,
            "-" => acc.checked_sub(*v)?,
            "*" => acc.checked_mul(*v)?,
            _ => return None,
        };
    }
    Some(acc)
}

pub fn generate(id: u64, difficulty: u8, rng: &mut Rng) -> Task {
    let (add_hi, start_hi) = if difficulty >= 4 { (50, 60) } else { (20, 20) };
    let start = rng.range(1, start_hi) as i64;
    let mut ops: Vec<(String, i64)> = Vec::with_capacity(n_ops(difficulty));
    for _ in 0..n_ops(difficulty) {
        // Multiplication only enters at difficulty >= 2, and stays rare
        // enough that values remain small (bounded by construction:
        // |v| <= 60 + 50*5 times at most 4^5 < 1e6).
        let mul = difficulty >= 2 && rng.bool(0.35);
        if mul {
            ops.push(("*".into(), 2 + rng.range(0, 3) as i64));
        } else if rng.bool(0.5) {
            ops.push(("+".into(), 1 + rng.range(0, add_hi) as i64));
        } else {
            ops.push(("-".into(), 1 + rng.range(0, add_hi) as i64));
        }
    }
    // swarmlint: allow(panic-path) — ops come from the bounded generator
    // above, not from the wire; fold only errors on hostile programs.
    let answer = fold(start, &ops).expect("generated ops are well-formed and bounded");
    let prompt = {
        let mut s = start.to_string();
        for (op, v) in &ops {
            s.push(':');
            s.push_str(op);
            s.push_str(&v.to_string());
        }
        s.push_str("=?");
        s
    };
    let ops_json = Json::Arr(
        ops.iter()
            .map(|(op, v)| Json::Arr(vec![Json::Str(op.clone()), Json::from(*v)]))
            .collect(),
    );
    Task {
        id,
        env: "chain",
        prompt,
        difficulty,
        payload: Json::obj(vec![
            ("answer", answer.to_string().into()),
            ("start", start.into()),
            ("ops", ops_json),
        ]),
    }
}

/// Refold the hidden op chain and compare against the completion's final
/// integer (tolerant extraction shared with the math env).
pub fn verify(task: &Task, completion: &str) -> bool {
    let Some(start) = task.payload.get("start").and_then(Json::as_f64) else {
        return false;
    };
    let Some(ops) = decode_ops(&task.payload) else {
        return false;
    };
    let Some(want) = fold(start as i64, &ops) else {
        return false;
    };
    super::math::extract_answer(completion) == Some(want)
}

fn decode_ops(payload: &Json) -> Option<Vec<(String, i64)>> {
    payload
        .get("ops")?
        .as_arr()?
        .iter()
        .map(|pair| {
            Some((
                pair.idx(0)?.as_str()?.to_string(),
                pair.idx(1)?.as_f64()? as i64,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ops(spec: &[(&str, i64)]) -> Vec<(String, i64)> {
        spec.iter().map(|(o, v)| (o.to_string(), *v)).collect()
    }

    #[test]
    fn folds_left_to_right_without_precedence() {
        // ((7+3)*2)-4 = 16, NOT 7+(3*2)-4 = 9.
        assert_eq!(fold(7, &ops(&[("+", 3), ("*", 2), ("-", 4)])), Some(16));
        assert_eq!(fold(5, &ops(&[])), Some(5));
        assert_eq!(fold(5, &ops(&[("/", 2)])), None);
    }

    #[test]
    fn generated_tasks_verify_with_reference_answer() {
        let mut rng = Rng::new(13);
        for d in 0..=MAX_DIFFICULTY {
            for i in 0..50 {
                let t = generate(i, d, &mut rng);
                assert!(verify(&t, t.answer()), "{t:?}");
                assert!(!verify(&t, "999999999"), "{t:?}");
                assert_eq!(t.prompt.matches(':').count(), n_ops(d), "{t:?}");
                assert!(t.prompt.ends_with("=?"), "{t:?}");
            }
        }
    }

    #[test]
    fn chain_differs_from_precedence_semantics() {
        // Find a generated chain whose left-to-right answer differs from
        // what precedence evaluation of the "same" expression would give —
        // the reason this is a distinct environment, not math rebranded.
        let mut rng = Rng::new(17);
        let mut diverged = false;
        for i in 0..200 {
            let t = generate(i, 2, &mut rng);
            let expr = t.prompt.trim_end_matches("=?").replace(':', "");
            if let Some(prec) = super::super::math::eval_expr(&expr) {
                if prec.to_string() != t.answer() {
                    diverged = true;
                    break;
                }
            }
        }
        assert!(diverged, "no chain diverged from precedence semantics in 200 draws");
    }

    #[test]
    fn malformed_payload_fails_closed() {
        let mut rng = Rng::new(19);
        let mut t = generate(0, 1, &mut rng);
        let honest = t.answer().to_string();
        // Drop the hidden ops: unverifiable, never a free pass.
        t.payload = Json::obj(vec![("answer", honest.clone().into())]);
        assert!(!verify(&t, &honest));
        // Unknown op word in a tampered payload: fails, no panic.
        let bad = Json::obj(vec![
            ("answer", honest.clone().into()),
            ("start", 5u64.into()),
            ("ops", Json::Arr(vec![Json::Arr(vec!["%".into(), Json::from(2u64)])])),
        ]);
        t.payload = bad;
        assert!(!verify(&t, &honest));
    }
}
