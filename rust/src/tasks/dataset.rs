//! Dataset assembly + the paper's deterministic sampling scheme, routed
//! entirely through the environment registry.
//!
//! §2.3.3: inference workers must not cherry-pick samples, so each node
//! derives its batch from `seed = node_address * step + submissions`; the
//! validator reproduces the draw from the same seed. That only works if
//! both sides rebuild the *same dataset* — so generation is a pure
//! function of `(registry, seed, env mix)`, the mix is an ordered list of
//! `(env, count)` pairs (the `--env-mix math=900,code=100,seq=200` knob),
//! and the produced [`Dataset`] carries the registry's fingerprint so a
//! silently different env set is refused at construction time instead of
//! surfacing as a bogus slash. §3.3.1: offline difficulty filtering keeps
//! tasks with base-model pass@8 in a band.

use super::Task;
use crate::util::rng::Rng;
use crate::verifier::Registry;

/// Ordered per-environment task counts. Order matters: the dataset is
/// generated mix-entry by mix-entry from one RNG stream, so two parties
/// must agree on the order (they do — both parse the same knob string).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EnvMix(pub Vec<(String, usize)>);

impl EnvMix {
    /// Parse the `--env-mix` knob: `"math=900,code=100,seq=200"`.
    pub fn parse(s: &str) -> anyhow::Result<EnvMix> {
        let mut out = Vec::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (name, count) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad env-mix entry {part:?} (want env=count)"))?;
            let name = name.trim();
            anyhow::ensure!(!name.is_empty(), "empty env name in env-mix {s:?}");
            anyhow::ensure!(
                !out.iter().any(|(n, _)| n == name),
                "env {name:?} repeated in env-mix {s:?}"
            );
            let count: usize = count
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad count in env-mix entry {part:?}"))?;
            out.push((name.to_string(), count));
        }
        anyhow::ensure!(!out.is_empty(), "empty env-mix");
        Ok(EnvMix(out))
    }

    /// Build from literal pairs (tests and harness configs).
    pub fn of(pairs: &[(&str, usize)]) -> EnvMix {
        EnvMix(pairs.iter().map(|(n, c)| (n.to_string(), *c)).collect())
    }

    /// Task count for one env (0 if absent from the mix).
    pub fn count(&self, env: &str) -> usize {
        self.0.iter().find(|(n, _)| n == env).map(|(_, c)| *c).unwrap_or(0)
    }

    pub fn total(&self) -> usize {
        // swarmlint: allow(float-fold) — usize sum; integer addition is
        // order-independent.
        self.0.iter().map(|(_, c)| c).sum()
    }

    /// Canonical knob rendering (`parse(render(m)) == m`).
    pub fn render(&self) -> String {
        self.0
            .iter()
            .map(|(n, c)| format!("{n}={c}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl Default for EnvMix {
    /// The historical two-domain default.
    fn default() -> Self {
        EnvMix::of(&[("math", 900), ("code", 100)])
    }
}

#[derive(Clone, Debug)]
pub struct DatasetConfig {
    pub seed: u64,
    /// Per-env task counts, in generation order.
    pub mix: EnvMix,
    /// Distribution over difficulties (unnormalized weights by level;
    /// clamped per env to its own ladder).
    pub difficulty_weights: Vec<f64>,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig {
            seed: 1337,
            mix: EnvMix::default(),
            difficulty_weights: vec![4.0, 3.0, 2.0, 1.0, 0.5, 0.25],
        }
    }
}

#[derive(Clone)]
pub struct Dataset {
    pub tasks: Vec<Task>,
    /// Fingerprint of the registry that generated this dataset
    /// ([`Registry::fingerprint`]): generators and validators check theirs
    /// against it at construction, so a silent env-set mismatch — which
    /// would turn §2.3.3 determinism checks into false slashes — fails
    /// fast instead.
    pub fingerprint: u64,
}

impl Dataset {
    /// Deterministically generate the full task set: mix entries in
    /// order, ids are indices, difficulties drawn from one RNG stream.
    /// Errors on a mix naming an env the registry doesn't have.
    pub fn generate(registry: &Registry, cfg: &DatasetConfig) -> anyhow::Result<Dataset> {
        let mut rng = Rng::new(cfg.seed);
        let mut tasks = Vec::with_capacity(cfg.mix.total());
        let mut id = 0u64;
        for (name, count) in &cfg.mix.0 {
            let env = registry
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("env-mix names unregistered env {name:?}"))?;
            for _ in 0..*count {
                let d = (rng.weighted(&cfg.difficulty_weights) as u8).min(env.max_difficulty());
                tasks.push(env.generate(id, d, &mut rng));
                id += 1;
            }
        }
        Ok(Dataset { tasks, fingerprint: registry.fingerprint() })
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn get(&self, id: u64) -> Option<&Task> {
        self.tasks.get(id as usize)
    }

    /// Retain only the given task ids (offline filtering output, §3.3.1).
    pub fn filtered(&self, keep: &[u64]) -> Dataset {
        let mut set = vec![false; self.tasks.len()];
        for &id in keep {
            if let Some(s) = set.get_mut(id as usize) {
                *s = true;
            }
        }
        Dataset {
            tasks: self
                .tasks
                .iter()
                .filter(|t| set[t.id as usize])
                .cloned()
                .collect(),
            fingerprint: self.fingerprint,
        }
    }

    /// Draw `k` task indices from the fixed-sampling seed. Both workers and
    /// validators call this — any divergence is a slashable offence.
    pub fn sample_for(&self, seed: u64, k: usize) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..k).map(|_| self.tasks[rng.usize(self.tasks.len())].id).collect()
    }

    /// Tasks owned by one environment.
    pub fn count_env(&self, env: &str) -> usize {
        self.tasks.iter().filter(|t| t.env == env).count()
    }

    /// `(env, count)` pairs in first-appearance order (observability).
    pub fn env_counts(&self) -> Vec<(&'static str, usize)> {
        let mut out: Vec<(&'static str, usize)> = Vec::new();
        for t in &self.tasks {
            match out.iter_mut().find(|(n, _)| *n == t.env) {
                Some((_, c)) => *c += 1,
                None => out.push((t.env, 1)),
            }
        }
        out
    }
}

/// The paper's sampling-seed formula (§2.3.3):
/// `seed = node_address * step + number_of_submissions_for_this_step`.
pub fn node_sample_seed(node_address: u64, step: u64, submissions: u64) -> u64 {
    node_address.wrapping_mul(step.wrapping_add(1)).wrapping_add(submissions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn reg() -> Registry {
        Registry::standard()
    }

    #[test]
    fn env_mix_parses_and_renders() {
        let m = EnvMix::parse("math=900, code=100,seq=200").unwrap();
        assert_eq!(m.count("math"), 900);
        assert_eq!(m.count("seq"), 200);
        assert_eq!(m.count("chain"), 0);
        assert_eq!(m.total(), 1200);
        assert_eq!(EnvMix::parse(&m.render()).unwrap(), m);
        assert!(EnvMix::parse("").is_err());
        assert!(EnvMix::parse("math").is_err());
        assert!(EnvMix::parse("math=x").is_err());
        assert!(EnvMix::parse("math=1,math=2").is_err());
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = DatasetConfig {
            mix: EnvMix::of(&[("math", 50), ("code", 10)]),
            ..Default::default()
        };
        let a = Dataset::generate(&reg(), &cfg).unwrap();
        let b = Dataset::generate(&reg(), &cfg).unwrap();
        assert_eq!(a.len(), 60);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.payload, y.payload);
        }
        assert_eq!(a.count_env("math"), 50);
        assert_eq!(a.count_env("code"), 10);
        assert_eq!(a.env_counts(), vec![("math", 50), ("code", 10)]);
        assert_eq!(a.fingerprint, reg().fingerprint());
    }

    #[test]
    fn unknown_env_in_mix_is_refused() {
        let cfg = DatasetConfig { mix: EnvMix::of(&[("martian", 5)]), ..Default::default() };
        assert!(Dataset::generate(&reg(), &cfg).is_err());
    }

    #[test]
    fn ids_are_indices() {
        let cfg = DatasetConfig {
            mix: EnvMix::of(&[("math", 20), ("code", 5), ("seq", 5), ("chain", 5)]),
            ..Default::default()
        };
        let d = Dataset::generate(&reg(), &cfg).unwrap();
        for (i, t) in d.tasks.iter().enumerate() {
            assert_eq!(t.id, i as u64);
            assert_eq!(d.get(t.id).unwrap().prompt, t.prompt);
        }
    }

    #[test]
    fn sample_reproducible_across_parties() {
        let cfg = DatasetConfig {
            mix: EnvMix::of(&[("math", 100), ("code", 20)]),
            ..Default::default()
        };
        let d = Dataset::generate(&reg(), &cfg).unwrap();
        let seed = node_sample_seed(0xABCD, 7, 2);
        assert_eq!(d.sample_for(seed, 16), d.sample_for(seed, 16));
        assert_ne!(
            d.sample_for(node_sample_seed(0xABCD, 7, 2), 16),
            d.sample_for(node_sample_seed(0xABCD, 7, 3), 16)
        );
        assert_ne!(
            d.sample_for(node_sample_seed(0xABCD, 7, 2), 16),
            d.sample_for(node_sample_seed(0xABCE, 7, 2), 16)
        );
    }

    #[test]
    fn filtering_keeps_subset_and_fingerprint() {
        let cfg = DatasetConfig { mix: EnvMix::of(&[("math", 30)]), ..Default::default() };
        let d = Dataset::generate(&reg(), &cfg).unwrap();
        let f = d.filtered(&[1, 5, 9]);
        assert_eq!(f.len(), 3);
        assert!(f.tasks.iter().all(|t| [1, 5, 9].contains(&t.id)));
        assert_eq!(f.fingerprint, d.fingerprint);
    }

    /// Byte-identical serialization of one task (what "identical dataset"
    /// means across parties: prompt, env, difficulty and the full hidden
    /// payload, rendered to canonical JSON text).
    fn task_bytes(t: &Task) -> String {
        format!("{}|{}|{}|{}|{}", t.id, t.env, t.difficulty, t.prompt, t.payload)
    }

    /// The regeneration-parity property behind §2.3.3 slashing: for
    /// *arbitrary env mixes* over arbitrary env subsets/orders, a
    /// worker-side and a validator-side dataset built from independently
    /// constructed registries are byte-identical — tasks, hidden payloads,
    /// fingerprint and the deterministic sample draw.
    #[test]
    fn prop_regeneration_parity_across_arbitrary_mixes() {
        prop::check(
            "worker/validator dataset regeneration parity",
            24,
            |rng, _| {
                let mut names = Registry::standard().names();
                rng.shuffle(&mut names);
                let n_envs = 1 + rng.usize(names.len());
                let mix = EnvMix(
                    names[..n_envs]
                        .iter()
                        .map(|n| (n.to_string(), 1 + rng.usize(40)))
                        .collect(),
                );
                (rng.next_u64(), mix)
            },
            |(seed, mix)| {
                let cfg = DatasetConfig { seed: *seed, mix: mix.clone(), ..Default::default() };
                let worker = Dataset::generate(&Registry::standard(), &cfg)
                    .map_err(|e| e.to_string())?;
                let validator = Dataset::generate(&Registry::standard(), &cfg)
                    .map_err(|e| e.to_string())?;
                prop::ensure_eq(worker.len(), mix.total(), "dataset size")?;
                prop::ensure_eq(worker.fingerprint, validator.fingerprint, "fingerprint")?;
                for (a, b) in worker.tasks.iter().zip(&validator.tasks) {
                    prop::ensure_eq(task_bytes(a), task_bytes(b), "task bytes")?;
                }
                let s = node_sample_seed(0xBEEF, 3, 1);
                prop::ensure_eq(
                    worker.sample_for(s, 8),
                    validator.sample_for(s, 8),
                    "sample draw",
                )
            },
        );
    }
}
