//! Dataset assembly + the paper's deterministic sampling scheme.
//!
//! §2.3.3: inference workers must not cherry-pick samples, so each node
//! derives its batch from `seed = node_address * step + submissions`; the
//! validator reproduces the draw from the same seed. §3.3.1: offline
//! difficulty filtering keeps tasks with base-model pass@8 in a band.

use super::{math, dsl, Task, TaskKind};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct DatasetConfig {
    pub seed: u64,
    pub n_math: usize,
    pub n_code: usize,
    /// Distribution over difficulties (unnormalized weights by level).
    pub difficulty_weights: Vec<f64>,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig {
            seed: 1337,
            n_math: 900,
            n_code: 100,
            difficulty_weights: vec![4.0, 3.0, 2.0, 1.0, 0.5, 0.25],
        }
    }
}

#[derive(Clone)]
pub struct Dataset {
    pub tasks: Vec<Task>,
}

impl Dataset {
    /// Deterministically generate the full task set (math then code, ids
    /// are indices).
    pub fn generate(cfg: &DatasetConfig) -> Dataset {
        let mut rng = Rng::new(cfg.seed);
        let mut tasks = Vec::with_capacity(cfg.n_math + cfg.n_code);
        for i in 0..cfg.n_math {
            let d = rng.weighted(&cfg.difficulty_weights) as u8;
            let d = d.min(math::MAX_DIFFICULTY);
            tasks.push(math::generate(i as u64, d, &mut rng));
        }
        for i in 0..cfg.n_code {
            let d = (rng.weighted(&cfg.difficulty_weights) as u8).min(3);
            tasks.push(dsl::generate((cfg.n_math + i) as u64, d, &mut rng));
        }
        Dataset { tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn get(&self, id: u64) -> Option<&Task> {
        self.tasks.get(id as usize)
    }

    /// Retain only the given task ids (offline filtering output, §3.3.1).
    pub fn filtered(&self, keep: &[u64]) -> Dataset {
        let mut set = vec![false; self.tasks.len()];
        for &id in keep {
            if let Some(s) = set.get_mut(id as usize) {
                *s = true;
            }
        }
        Dataset {
            tasks: self
                .tasks
                .iter()
                .filter(|t| set[t.id as usize])
                .cloned()
                .collect(),
        }
    }

    /// Draw `k` task indices from the fixed-sampling seed. Both workers and
    /// validators call this — any divergence is a slashable offence.
    pub fn sample_for(&self, seed: u64, k: usize) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..k).map(|_| self.tasks[rng.usize(self.tasks.len())].id).collect()
    }

    pub fn count_kind(&self, kind: TaskKind) -> usize {
        self.tasks.iter().filter(|t| t.kind == kind).count()
    }
}

/// The paper's sampling-seed formula (§2.3.3):
/// `seed = node_address * step + number_of_submissions_for_this_step`.
pub fn node_sample_seed(node_address: u64, step: u64, submissions: u64) -> u64 {
    node_address.wrapping_mul(step.wrapping_add(1)).wrapping_add(submissions)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let cfg = DatasetConfig { n_math: 50, n_code: 10, ..Default::default() };
        let a = Dataset::generate(&cfg);
        let b = Dataset::generate(&cfg);
        assert_eq!(a.len(), 60);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
        assert_eq!(a.count_kind(TaskKind::Math), 50);
        assert_eq!(a.count_kind(TaskKind::Code), 10);
    }

    #[test]
    fn ids_are_indices() {
        let d = Dataset::generate(&DatasetConfig { n_math: 20, n_code: 5, ..Default::default() });
        for (i, t) in d.tasks.iter().enumerate() {
            assert_eq!(t.id, i as u64);
            assert_eq!(d.get(t.id).unwrap().prompt, t.prompt);
        }
    }

    #[test]
    fn sample_reproducible_across_parties() {
        let d = Dataset::generate(&DatasetConfig { n_math: 100, n_code: 20, ..Default::default() });
        let seed = node_sample_seed(0xABCD, 7, 2);
        assert_eq!(d.sample_for(seed, 16), d.sample_for(seed, 16));
        assert_ne!(
            d.sample_for(node_sample_seed(0xABCD, 7, 2), 16),
            d.sample_for(node_sample_seed(0xABCD, 7, 3), 16)
        );
        assert_ne!(
            d.sample_for(node_sample_seed(0xABCD, 7, 2), 16),
            d.sample_for(node_sample_seed(0xABCE, 7, 2), 16)
        );
    }

    #[test]
    fn filtering_keeps_subset() {
        let d = Dataset::generate(&DatasetConfig { n_math: 30, n_code: 0, ..Default::default() });
        let f = d.filtered(&[1, 5, 9]);
        assert_eq!(f.len(), 3);
        assert!(f.tasks.iter().all(|t| [1, 5, 9].contains(&t.id)));
    }
}
