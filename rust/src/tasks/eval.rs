//! Held-out evaluation suites — the Table 1 benchmark analogues, derived
//! from the environment registry.
//!
//! The paper evaluates on AIME24/25, LiveCodeBench, GPQA-Diamond and
//! IFEval. Substitutions (DESIGN.md): each suite is a held-out seeded task
//! family probing the same axis (hard math, code, mixed generalization,
//! instruction/length following). A [`Suite`] is *data*, not an enum: a
//! name, a held-out seed, a cycled list of `(env, difficulty)` templates,
//! and a scoring mode — all task generation and correctness scoring go
//! through `verifier::Registry`, the same dispatch path the trainer and
//! the TOPLOC validator use, so the two verification paths cannot drift.
//!
//! Every registered environment also contributes a *derived* held-out
//! suite ([`Suite::for_env`]) built from its own
//! `Environment::eval_difficulties` ladder — plug in an env, get its eval
//! for free ([`Suite::standard`] appends them automatically).

use super::{dataset::Dataset, Task};
use crate::util::rng::Rng;
use crate::verifier::{Environment, Registry};

/// How a suite scores one completion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scoring {
    /// Binary correctness through the task's environment verifier.
    Correctness,
    /// IFEval analogue: fraction of completions within tolerance of the
    /// requested thinking budget (correctness ignored).
    LengthFollow,
}

/// One held-out suite.
#[derive(Clone, Debug)]
pub struct Suite {
    pub name: String,
    /// Held-out seed: disjoint from every training dataset seed.
    seed: u64,
    /// `(env, difficulty)` templates, cycled across task indices.
    entries: Vec<(String, u8)>,
    pub scoring: Scoring,
}

/// Base of the held-out seed space (training datasets use small
/// user-picked seeds; everything here lives under this prefix).
const EVAL_SEED_BASE: u64 = 0xE11A_0000;

/// FNV-1a over an env name: the per-env derived-suite seed offset.
fn name_seed(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl Suite {
    /// AIME24 analogue: hardest math levels (4-5).
    pub fn math_hard() -> Suite {
        Suite {
            name: "MATH-HARD (AIME24 analogue)".into(),
            seed: EVAL_SEED_BASE + 1,
            entries: vec![("math".into(), 4), ("math".into(), 5)],
            scoring: Scoring::Correctness,
        }
    }

    /// AIME25 analogue: same distribution, different seed.
    pub fn math_hard2() -> Suite {
        Suite {
            name: "MATH-HARD-2 (AIME25 analogue)".into(),
            seed: EVAL_SEED_BASE + 2,
            entries: vec![("math".into(), 4), ("math".into(), 5)],
            scoring: Scoring::Correctness,
        }
    }

    /// LiveCodeBench analogue: held-out code tasks (difficulty 2-3).
    pub fn code() -> Suite {
        Suite {
            name: "CODE (LiveCodeBench analogue)".into(),
            seed: EVAL_SEED_BASE + 3,
            entries: vec![("code".into(), 2), ("code".into(), 3)],
            scoring: Scoring::Correctness,
        }
    }

    /// GPQA analogue: cross-domain generalization — cycles *every*
    /// registered env near the top of its ladder, so the suite widens by
    /// itself as environments are plugged in.
    pub fn mixed(registry: &Registry) -> Suite {
        Suite {
            name: "MIXED (GPQA-Diamond analogue)".into(),
            seed: EVAL_SEED_BASE + 4,
            entries: registry
                .envs()
                .map(|e| (e.name().to_string(), e.max_difficulty().saturating_sub(1)))
                .collect(),
            scoring: Scoring::Correctness,
        }
    }

    /// IFEval analogue: length-budget following over easy math prompts.
    pub fn length_follow() -> Suite {
        Suite {
            name: "LENGTH-FOLLOW (IFEval analogue)".into(),
            seed: EVAL_SEED_BASE + 5,
            entries: vec![("math".into(), 1)],
            scoring: Scoring::LengthFollow,
        }
    }

    /// The env's derived held-out suite: its own
    /// [`Environment::eval_difficulties`] ladder under a name-keyed
    /// held-out seed. This is the eval-suite hook of the plugin API.
    pub fn for_env(env: &dyn Environment) -> Suite {
        Suite {
            name: format!("EVAL-{} (held out)", env.name()),
            seed: EVAL_SEED_BASE ^ name_seed(env.name()),
            entries: env
                .eval_difficulties()
                .into_iter()
                .map(|d| (env.name().to_string(), d))
                .collect(),
            scoring: Scoring::Correctness,
        }
    }

    /// The full evaluation battery: the five classic analogues plus one
    /// derived suite per registered environment.
    pub fn standard(registry: &Registry) -> Vec<Suite> {
        let mut out = vec![
            Suite::math_hard(),
            Suite::math_hard2(),
            Suite::code(),
            Suite::mixed(registry),
            Suite::length_follow(),
        ];
        out.extend(registry.envs().map(Suite::for_env));
        out
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Generate the suite's first `n` tasks through the registry. Ids
    /// start at 1_000_000 so they never collide with train ids.
    pub fn tasks(&self, registry: &Registry, n: usize) -> anyhow::Result<Vec<Task>> {
        anyhow::ensure!(!self.entries.is_empty(), "suite {:?} has no entries", self.name);
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let (env, d) = &self.entries[i % self.entries.len()];
            out.push(registry.generate(env, 1_000_000 + i as u64, *d, &mut rng)?);
        }
        Ok(out)
    }

    /// Score one completion for this suite (correctness through the
    /// registry — the same path the reward and TOPLOC checks use).
    pub fn score(
        &self,
        registry: &Registry,
        task: &Task,
        completion: &str,
        completion_len: usize,
        target_len: Option<usize>,
    ) -> f64 {
        match self.scoring {
            Scoring::LengthFollow => {
                let target = target_len.unwrap_or(0) as f64;
                let tol = (target * 0.25).max(8.0);
                if (completion_len as f64 - target).abs() <= tol {
                    1.0
                } else {
                    0.0
                }
            }
            Scoring::Correctness => {
                if registry.verify(task, completion) {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Confirm eval tasks don't collide with a training dataset (prompt-level).
pub fn overlap_with_train(
    registry: &Registry,
    suite: &Suite,
    train: &Dataset,
    n: usize,
) -> anyhow::Result<usize> {
    let eval_tasks = suite.tasks(registry, n)?;
    Ok(eval_tasks
        .iter()
        .filter(|e| train.tasks.iter().any(|t| t.prompt == e.prompt))
        .count())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::dataset::{DatasetConfig, EnvMix};

    fn reg() -> Registry {
        Registry::standard()
    }

    #[test]
    fn suites_are_deterministic_and_distinct() {
        let registry = reg();
        for s in Suite::standard(&registry) {
            let a = s.tasks(&registry, 20).unwrap();
            let b = s.tasks(&registry, 20).unwrap();
            assert_eq!(a.len(), 20);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prompt, y.prompt);
            }
        }
        let m1 = Suite::math_hard().tasks(&registry, 20).unwrap();
        let m2 = Suite::math_hard2().tasks(&registry, 20).unwrap();
        assert!(m1.iter().zip(&m2).any(|(a, b)| a.prompt != b.prompt));
    }

    #[test]
    fn every_registered_env_gets_a_derived_suite() {
        let registry = reg();
        let suites = Suite::standard(&registry);
        for env in registry.envs() {
            let suite = suites
                .iter()
                .find(|s| s.name.contains(&format!("EVAL-{}", env.name())))
                .unwrap_or_else(|| panic!("no derived suite for {}", env.name()));
            let tasks = suite.tasks(&registry, 10).unwrap();
            assert!(tasks.iter().all(|t| t.env == env.name()));
            // The derived ladder is the env's own hook.
            let ladder = env.eval_difficulties();
            for (i, t) in tasks.iter().enumerate() {
                assert_eq!(t.difficulty, ladder[i % ladder.len()].min(env.max_difficulty()));
            }
        }
    }

    #[test]
    fn mixed_suite_spans_all_envs() {
        let registry = reg();
        let tasks = Suite::mixed(&registry).tasks(&registry, 2 * registry.len()).unwrap();
        for name in registry.names() {
            assert!(tasks.iter().any(|t| t.env == name), "mixed suite misses {name}");
        }
    }

    #[test]
    fn reference_answers_score_one() {
        let registry = reg();
        for s in Suite::standard(&registry) {
            if s.scoring != Scoring::Correctness {
                continue;
            }
            for t in s.tasks(&registry, 15).unwrap() {
                assert_eq!(s.score(&registry, &t, t.answer(), t.answer().len(), None), 1.0);
            }
        }
    }

    #[test]
    fn length_follow_scores_budget() {
        let registry = reg();
        let s = Suite::length_follow();
        let t = &s.tasks(&registry, 1).unwrap()[0];
        assert_eq!(s.score(&registry, t, "x", 64, Some(64)), 1.0);
        assert_eq!(s.score(&registry, t, "x", 64, Some(128)), 0.0);
    }

    #[test]
    fn minimal_train_eval_overlap() {
        let registry = reg();
        let train = Dataset::generate(
            &registry,
            &DatasetConfig {
                mix: EnvMix::of(&[("math", 200), ("code", 40)]),
                ..Default::default()
            },
        )
        .unwrap();
        // Hard suites draw from much larger value ranges; incidental prompt
        // collisions with the easy-heavy train set must be rare.
        let ov = overlap_with_train(&registry, &Suite::math_hard(), &train, 50).unwrap();
        assert!(ov <= 2, "{ov}");
    }
}
