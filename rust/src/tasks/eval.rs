//! Held-out evaluation suites — the Table 1 benchmark analogues.
//!
//! The paper evaluates on AIME24/25, LiveCodeBench, GPQA-Diamond and
//! IFEval. Substitutions (DESIGN.md): each suite is a held-out seeded task
//! family probing the same axis (hard math, code, mixed generalization,
//! instruction/length following).

use super::{dataset::Dataset, dsl, math, Task, TaskKind};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// AIME analogue: hardest math levels (4-5).
    MathHard,
    /// AIME25 analogue: same distribution, different seed.
    MathHard2,
    /// LiveCodeBench analogue: held-out code tasks (difficulty 2-3).
    Code,
    /// GPQA analogue: mixed hard math + code generalization set.
    Mixed,
    /// IFEval analogue: length-budget following (score = fraction of
    /// completions within tolerance of the requested budget).
    LengthFollow,
}

pub const ALL_SUITES: [Suite; 5] =
    [Suite::MathHard, Suite::MathHard2, Suite::Code, Suite::Mixed, Suite::LengthFollow];

impl Suite {
    pub fn name(&self) -> &'static str {
        match self {
            Suite::MathHard => "MATH-HARD (AIME24 analogue)",
            Suite::MathHard2 => "MATH-HARD-2 (AIME25 analogue)",
            Suite::Code => "CODE (LiveCodeBench analogue)",
            Suite::Mixed => "MIXED (GPQA-Diamond analogue)",
            Suite::LengthFollow => "LENGTH-FOLLOW (IFEval analogue)",
        }
    }

    /// Held-out seeds: disjoint from every training dataset seed.
    fn seed(&self) -> u64 {
        match self {
            Suite::MathHard => 0xE11A_0001,
            Suite::MathHard2 => 0xE11A_0002,
            Suite::Code => 0xE11A_0003,
            Suite::Mixed => 0xE11A_0004,
            Suite::LengthFollow => 0xE11A_0005,
        }
    }

    pub fn tasks(&self, n: usize) -> Vec<Task> {
        let mut rng = Rng::new(self.seed());
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let id = 1_000_000 + i as u64; // never collides with train ids
            let t = match self {
                Suite::MathHard | Suite::MathHard2 => {
                    math::generate(id, 4 + (i % 2) as u8, &mut rng)
                }
                Suite::Code => dsl::generate(id, 2 + (i % 2) as u8, &mut rng),
                Suite::Mixed => {
                    if i % 2 == 0 {
                        math::generate(id, 3, &mut rng)
                    } else {
                        dsl::generate(id, 2, &mut rng)
                    }
                }
                // Length-follow reuses easy math but scores on budget
                // adherence, not correctness.
                Suite::LengthFollow => math::generate(id, 1, &mut rng),
            };
            out.push(t);
        }
        out
    }

    /// Score one completion for this suite.
    pub fn score(&self, task: &Task, completion: &str, completion_len: usize, target_len: Option<usize>) -> f64 {
        match self {
            Suite::LengthFollow => {
                let target = target_len.unwrap_or(0) as f64;
                let tol = (target * 0.25).max(8.0);
                if (completion_len as f64 - target).abs() <= tol {
                    1.0
                } else {
                    0.0
                }
            }
            _ => {
                let ok = match task.kind {
                    TaskKind::Math => math::verify(task, completion),
                    TaskKind::Code => dsl::verify(task, completion),
                };
                if ok {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Confirm eval tasks don't collide with a training dataset (prompt-level).
pub fn overlap_with_train(suite: &Suite, train: &Dataset, n: usize) -> usize {
    let eval_tasks = suite.tasks(n);
    eval_tasks
        .iter()
        .filter(|e| train.tasks.iter().any(|t| t.prompt == e.prompt))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::dataset::DatasetConfig;

    #[test]
    fn suites_are_deterministic_and_distinct() {
        for s in ALL_SUITES {
            let a = s.tasks(20);
            let b = s.tasks(20);
            assert_eq!(a.len(), 20);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prompt, y.prompt);
            }
        }
        let m1 = Suite::MathHard.tasks(20);
        let m2 = Suite::MathHard2.tasks(20);
        assert!(m1.iter().zip(&m2).any(|(a, b)| a.prompt != b.prompt));
    }

    #[test]
    fn reference_answers_score_one() {
        for s in [Suite::MathHard, Suite::Code, Suite::Mixed] {
            for t in s.tasks(15) {
                assert_eq!(s.score(&t, &t.answer, t.answer.len(), None), 1.0);
            }
        }
    }

    #[test]
    fn length_follow_scores_budget() {
        let s = Suite::LengthFollow;
        let t = &s.tasks(1)[0];
        assert_eq!(s.score(t, "x", 64, Some(64)), 1.0);
        assert_eq!(s.score(t, "x", 64, Some(128)), 0.0);
    }

    #[test]
    fn minimal_train_eval_overlap() {
        let train = Dataset::generate(&DatasetConfig { n_math: 200, n_code: 40, ..Default::default() });
        // Hard suites draw from much larger value ranges; incidental prompt
        // collisions with the easy-heavy train set must be rare.
        let ov = overlap_with_train(&Suite::MathHard, &train, 50);
        assert!(ov <= 2, "{ov}");
    }
}
