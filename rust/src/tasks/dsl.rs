//! `CodeEnv` ("code"): mini stack DSL — the "python coding competition"
//! stand-in (§2.1.3), packaged as one [`Environment`] plugin.
//!
//! A program is a sequence of words applied left-to-right to an integer
//! list (`"sort rev"` sorts then reverses). Tasks show input/output example
//! pairs; the model writes the program; the verifier *executes* it against
//! hidden unit tests — sandboxed exactly like the paper sandboxes LLM
//! code: hard limits on program length, list size and value magnitude,
//! and binary all-tests-pass rewards to discourage reward hacking.
//!
//! Payload: `{"answer": "<program>", "tests": [[[in...],[out...]], ...]}` —
//! the hidden unit tests ride the env-owned payload (list values are
//! bounded by [`MAX_ABS_VALUE`], well inside f64-exact JSON range).

use super::Task;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::verifier::Environment;

/// The "code" environment plugin.
pub struct CodeEnv;

impl Environment for CodeEnv {
    fn name(&self) -> &'static str {
        "code"
    }
    fn description(&self) -> &'static str {
        "stack-DSL programs under hidden unit tests (SYNTHETIC-1 analogue)"
    }
    fn max_difficulty(&self) -> u8 {
        3
    }
    fn generate(&self, id: u64, difficulty: u8, rng: &mut Rng) -> Task {
        generate(id, difficulty, rng)
    }
    fn verify(&self, task: &Task, completion: &str) -> bool {
        verify(task, completion)
    }
    fn corrupt_answer(&self, _answer: &str, rng: &mut Rng) -> String {
        // Pretraining noise: a random (likely wrong) op word.
        OPS[rng.usize(OPS.len())].to_string()
    }
}

pub const OPS: &[&str] = &[
    "rev", "sort", "inc", "dec", "dbl", "sum", "max", "min", "len", "head", "tail",
];

/// Sandbox limits (the "code sanitization" of §2.1.3).
pub const MAX_PROGRAM_OPS: usize = 8;
pub const MAX_LIST_LEN: usize = 64;
pub const MAX_ABS_VALUE: i64 = 1_000_000_000;

#[derive(Clone, Debug, PartialEq)]
pub enum DslError {
    UnknownOp(String),
    ProgramTooLong,
    EmptyList(&'static str),
    ValueOverflow,
    EmptyProgram,
}

impl std::fmt::Display for DslError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DslError::UnknownOp(op) => write!(f, "unknown op {op:?}"),
            DslError::ProgramTooLong => write!(f, "program too long"),
            DslError::EmptyList(op) => write!(f, "empty list for {op}"),
            DslError::ValueOverflow => write!(f, "value out of sandbox bounds"),
            DslError::EmptyProgram => write!(f, "empty program"),
        }
    }
}

impl std::error::Error for DslError {}

pub fn apply_op(op: &str, mut xs: Vec<i64>) -> Result<Vec<i64>, DslError> {
    match op {
        "rev" => {
            xs.reverse();
            Ok(xs)
        }
        "sort" => {
            xs.sort();
            Ok(xs)
        }
        "inc" => xs.into_iter().map(|x| bound(x + 1)).collect(),
        "dec" => xs.into_iter().map(|x| bound(x - 1)).collect(),
        "dbl" => xs.into_iter().map(|x| bound(x * 2)).collect(),
        // swarmlint: allow(float-fold) — i64 sum; integer addition is
        // order-independent (and `bound` rejects overflow-range results).
        "sum" => Ok(vec![bound(xs.iter().sum())?]),
        "max" => xs.iter().max().map(|&m| vec![m]).ok_or(DslError::EmptyList("max")),
        "min" => xs.iter().min().map(|&m| vec![m]).ok_or(DslError::EmptyList("min")),
        "len" => Ok(vec![xs.len() as i64]),
        "head" => xs.first().map(|&h| vec![h]).ok_or(DslError::EmptyList("head")),
        "tail" => {
            if xs.is_empty() {
                Err(DslError::EmptyList("tail"))
            } else {
                Ok(xs[1..].to_vec())
            }
        }
        other => Err(DslError::UnknownOp(other.to_string())),
    }
}

fn bound(v: i64) -> Result<i64, DslError> {
    if v.abs() > MAX_ABS_VALUE {
        Err(DslError::ValueOverflow)
    } else {
        Ok(v)
    }
}

/// Parse + execute a program text against one input (the unit-test runner).
pub fn run(program: &str, input: &[i64]) -> Result<Vec<i64>, DslError> {
    let words: Vec<&str> = program.split_whitespace().collect();
    if words.is_empty() {
        return Err(DslError::EmptyProgram);
    }
    if words.len() > MAX_PROGRAM_OPS {
        return Err(DslError::ProgramTooLong);
    }
    if input.len() > MAX_LIST_LEN {
        return Err(DslError::ValueOverflow);
    }
    let mut xs = input.to_vec();
    for w in words {
        xs = apply_op(w, xs)?;
    }
    Ok(xs)
}

pub fn render_list(xs: &[i64]) -> String {
    let inner: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", inner.join(","))
}

pub fn parse_list(s: &str) -> Option<Vec<i64>> {
    let s = s.trim();
    let inner = s.strip_prefix('[')?.strip_suffix(']')?;
    if inner.is_empty() {
        return Some(Vec::new());
    }
    inner.split(',').map(|p| p.trim().parse::<i64>().ok()).collect()
}

/// Generate a code task: sample a secret program (1 op at difficulty 0-1,
/// 2 ops above), render two example IO pairs in the prompt, keep two more
/// as hidden unit tests.
pub fn generate(id: u64, difficulty: u8, rng: &mut Rng) -> Task {
    let n_ops = if difficulty <= 1 { 1 } else { 2 };
    loop {
        let mut ops: Vec<&str> = Vec::new();
        for _ in 0..n_ops {
            ops.push(OPS[rng.usize(OPS.len())]);
        }
        let program = ops.join(" ");
        // 4 random inputs: 2 shown, 2 hidden.
        let mut pairs = Vec::new();
        let mut degenerate = false;
        for _ in 0..4 {
            let len = 2 + rng.usize(3 + difficulty as usize);
            let hi = if difficulty == 0 { 10 } else { 30 };
            let input: Vec<i64> = (0..len).map(|_| rng.range(0, hi) as i64).collect();
            match run(&program, &input) {
                Ok(out) => pairs.push((input, out)),
                Err(_) => {
                    degenerate = true;
                    break;
                }
            }
        }
        if degenerate {
            continue;
        }
        // Reject programs indistinguishable from identity on the examples
        // (no learnable signal, and "identity" hacks would pass).
        if pairs.iter().all(|(i, o)| i == o) {
            continue;
        }
        let prompt = format!(
            "f{}={} f{}={} f?",
            render_list(&pairs[0].0),
            render_list(&pairs[0].1),
            render_list(&pairs[1].0),
            render_list(&pairs[1].1),
        );
        return Task {
            id,
            env: "code",
            prompt,
            difficulty,
            payload: Json::obj(vec![
                ("answer", program.into()),
                ("tests", encode_tests(&pairs[2..])),
            ]),
        };
    }
}

/// Hidden unit tests -> payload JSON: `[[[in...],[out...]], ...]`.
fn encode_tests(pairs: &[(Vec<i64>, Vec<i64>)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|(i, o)| Json::Arr(vec![Json::from(i.clone()), Json::from(o.clone())]))
            .collect(),
    )
}

/// Payload JSON -> hidden unit tests (inverse of [`encode_tests`]).
/// `None` on a malformed payload — which `verify` scores as failure.
pub fn decode_tests(payload: &Json) -> Option<Vec<(Vec<i64>, Vec<i64>)>> {
    let list = |j: &Json| -> Option<Vec<i64>> {
        j.as_arr()?.iter().map(|v| v.as_f64().map(|f| f as i64)).collect()
    };
    payload
        .get("tests")?
        .as_arr()?
        .iter()
        .map(|pair| Some((list(pair.idx(0)?)?, list(pair.idx(1)?)?)))
        .collect()
}

/// Binary all-tests-pass verification (§3.1.1: deliberately no partial
/// credit for passing a subset, to discourage reward hacking).
pub fn verify(task: &Task, completion: &str) -> bool {
    let program: String = completion.chars().filter(|c| *c != '~').collect();
    let program = program.trim();
    if program.is_empty() {
        return false;
    }
    let Some(tests) = decode_tests(&task.payload) else {
        return false;
    };
    tests.iter().all(|(input, want)| match run(program, input) {
        Ok(got) => &got == want,
        Err(_) => false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn ops_semantics() {
        assert_eq!(run("sort", &[3, 1, 2]).unwrap(), vec![1, 2, 3]);
        assert_eq!(run("sort rev", &[3, 1, 2]).unwrap(), vec![3, 2, 1]);
        assert_eq!(run("sum", &[3, 1, 2]).unwrap(), vec![6]);
        assert_eq!(run("inc dbl", &[1, 2]).unwrap(), vec![4, 6]);
        assert_eq!(run("tail head", &[9, 8, 7]).unwrap(), vec![8]);
        assert_eq!(run("len", &[]).unwrap(), vec![0]);
    }

    #[test]
    fn sandbox_limits() {
        assert_eq!(run("bad", &[1]), Err(DslError::UnknownOp("bad".into())));
        assert_eq!(run("", &[1]), Err(DslError::EmptyProgram));
        let long = vec!["inc"; MAX_PROGRAM_OPS + 1].join(" ");
        assert_eq!(run(&long, &[1]), Err(DslError::ProgramTooLong));
        assert_eq!(run("head", &[]), Err(DslError::EmptyList("head")));
        let big = vec![MAX_ABS_VALUE];
        assert_eq!(run("dbl", &big), Err(DslError::ValueOverflow));
    }

    #[test]
    fn list_roundtrip() {
        for xs in [vec![], vec![5], vec![1, -2, 30]] {
            assert_eq!(parse_list(&render_list(&xs)), Some(xs));
        }
        assert_eq!(parse_list("[1,,2]"), None);
        assert_eq!(parse_list("1,2"), None);
    }

    #[test]
    fn generated_tasks_verify_with_reference_program() {
        let mut rng = Rng::new(2);
        for d in 0..=3u8 {
            for i in 0..40 {
                let t = generate(i, d, &mut rng);
                assert!(verify(&t, t.answer()), "{t:?}");
                assert_eq!(decode_tests(&t.payload).unwrap().len(), 2);
            }
        }
    }

    #[test]
    fn wrong_programs_mostly_fail() {
        let mut rng = Rng::new(3);
        let mut wrong_pass = 0;
        let n = 60;
        for i in 0..n {
            let t = generate(i, 2, &mut rng);
            // A fixed wrong guess.
            if t.answer() != "rev" && verify(&t, "rev") {
                wrong_pass += 1;
            }
        }
        // Collisions possible (different program, same behaviour on the
        // hidden tests) but must be rare.
        assert!(wrong_pass < n / 4, "{wrong_pass}");
    }

    #[test]
    fn tests_roundtrip_through_payload() {
        let pairs = vec![(vec![1, 2], vec![2, 1]), (vec![], vec![0])];
        let payload = Json::obj(vec![("tests", encode_tests(&pairs))]);
        assert_eq!(decode_tests(&payload), Some(pairs));
        // A task whose payload lost its hidden tests never verifies.
        let mut rng = Rng::new(5);
        let mut t = generate(0, 1, &mut rng);
        let answer = t.answer().to_string();
        t.payload = Json::obj(vec![("answer", answer.clone().into())]);
        assert!(!verify(&t, &answer));
    }

    #[test]
    fn prop_run_is_deterministic_and_bounded() {
        prop::check("dsl deterministic", 96, |rng, size| {
            let n_ops = 1 + rng.usize(3);
            let prog: Vec<&str> = (0..n_ops).map(|_| OPS[rng.usize(OPS.len())]).collect();
            let input: Vec<i64> = (0..rng.usize(size as usize % 20 + 2))
                .map(|_| rng.range(0, 50) as i64)
                .collect();
            (prog.join(" "), input)
        }, |(prog, input)| {
            let a = run(prog, input);
            let b = run(prog, input);
            prop::ensure_eq(a.clone(), b, "deterministic")?;
            if let Ok(out) = a {
                prop::ensure(
                    out.iter().all(|v| v.abs() <= MAX_ABS_VALUE),
                    "bounded",
                )?;
            }
            Ok(())
        });
    }
}
