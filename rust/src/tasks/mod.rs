//! Training tasks with verifiable rewards (paper §3.1.1).
//!
//! The paper curates 285k tasks (259k math from NuminaMath-1.5/Deepscaler,
//! 26k Python coding problems from SYNTHETIC-1). Substitution (DESIGN.md):
//! synthetic arithmetic tasks verified symbolically, and list-manipulation
//! programs in a mini stack DSL verified by hidden unit tests — the same
//! binary-reward structure at a scale a tiny model can learn.

pub mod dataset;
pub mod dsl;
pub mod eval;
pub mod math;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Math,
    Code,
}

/// One verifiable task. `prompt` and `answer` are plain text in the
/// tokenizer alphabet; code tasks additionally carry hidden unit tests.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    pub kind: TaskKind,
    pub prompt: String,
    /// Reference answer (math) or reference program (code).
    pub answer: String,
    /// Difficulty knob used by the generators (0 = easiest).
    pub difficulty: u8,
    /// Hidden unit tests for code tasks: (input list, expected output).
    pub tests: Vec<(Vec<i64>, Vec<i64>)>,
}

impl Task {
    /// Render the prompt with an optional thinking-budget prefix
    /// (paper §3.1.2: "Think for N tokens before giving a response" —
    /// here `<N|` in the char vocabulary).
    pub fn prompt_with_budget(&self, target_len: Option<usize>) -> String {
        match target_len {
            Some(n) => format!("<{n}|{}", self.prompt),
            None => self.prompt.clone(),
        }
    }
}
