//! Training tasks with verifiable rewards (paper §3.1.1) — env-agnostic.
//!
//! The paper curates 285k tasks (259k math from NuminaMath-1.5/Deepscaler,
//! 26k Python coding problems from SYNTHETIC-1); its successors open the
//! task surface into pluggable environment hubs. This layer mirrors that:
//! a [`Task`] carries no domain knowledge of its own — just an env id
//! naming its owning [`crate::verifier::Environment`] plugin, a prompt in
//! the tokenizer alphabet, and an env-owned JSON payload holding whatever
//! hidden verification state that env needs (reference answers, unit
//! tests, generating rules, ...).
//!
//! The environments shipped in-tree, one file each:
//! - [`math`] — symbolic arithmetic (`MathEnv`, "math")
//! - [`dsl`] — mini stack-DSL programs under hidden unit tests
//!   (`CodeEnv`, "code")
//! - [`seq`] — sequence extrapolation from a hidden generating rule
//!   (`SeqEnv`, "seq")
//! - [`chain`] — left-to-right multi-step arithmetic chains
//!   (`ChainEnv`, "chain")
//!
//! Adding a fifth is the same shape: one file implementing `Environment`,
//! one `Registry::register` call — nothing here changes. Dataset assembly
//! ([`dataset`]) and held-out evaluation ([`eval`]) dispatch purely
//! through the registry.
//!
//! **Payload contract:** every env stores the reference completion under
//! the `"answer"` key ([`Task::answer`]); all other keys are env-private.
//! Payloads must round-trip losslessly through JSON text (enforced by a
//! registry property test) so both swarm sides reconstruct identical
//! hidden state.

pub mod chain;
pub mod dataset;
pub mod dsl;
pub mod eval;
pub mod math;
pub mod seq;

use crate::util::json::Json;

/// One verifiable task. `prompt` is plain text in the tokenizer alphabet;
/// `payload` is owned by the environment named in `env`.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    /// Registry key of the owning environment (`verifier::Registry`).
    pub env: &'static str,
    pub prompt: String,
    /// Difficulty knob used by the generators (0 = easiest).
    pub difficulty: u8,
    /// Env-owned hidden state. Contract: `"answer"` holds the reference
    /// completion; everything else is private to the env's verifier.
    pub payload: Json,
}

impl Task {
    /// The reference completion (the payload's `"answer"` key). Empty for
    /// a payload violating the contract — which the registry tests treat
    /// as a broken environment.
    pub fn answer(&self) -> &str {
        self.payload.get("answer").and_then(Json::as_str).unwrap_or("")
    }

    /// Render the prompt with an optional thinking-budget prefix
    /// (paper §3.1.2: "Think for N tokens before giving a response" —
    /// here `<N|` in the char vocabulary).
    pub fn prompt_with_budget(&self, target_len: Option<usize>) -> String {
        match target_len {
            Some(n) => format!("<{n}|{}", self.prompt),
            None => self.prompt.clone(),
        }
    }
}
