//! `SeqEnv` ("seq"): sequence extrapolation — the first environment added
//! *through* the pluggable registry rather than wired into it. The entire
//! integration surface is this file plus one `Registry::register` call
//! (see `verifier::Registry::standard`), which is the point: proof that
//! "adding an environment = implementing one trait" holds.
//!
//! A task shows the first terms of a hidden integer sequence and asks for
//! the next one (`"3,5,7,9,?"`). The generating rule is *hidden
//! verification state* in the env-owned payload: the verifier replays the
//! rule independently instead of trusting the stored answer — the same
//! symbolic-verification flavor as the math env, over a rule family a
//! prompt-matcher cannot shortcut.
//!
//! Difficulty ladder:
//!   0: arithmetic, small start/step, 3 shown terms      "2,4,6,?"
//!   1: arithmetic, larger values, 4 shown terms         "17,29,41,53,?"
//!   2: geometric (ratio 2-3), 4 shown terms             "3,6,12,24,?"
//!   3: alternating increments (+a,+b repeating), 5 terms "1,4,6,9,11,?"
//!   4: second-order (each term = sum of previous two),  "2,3,5,8,13,?"
//!
//! Payload: `{"answer": "<next>", "rule": {"kind": ..., ...}, "shown": n}`.

use super::Task;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::verifier::Environment;

pub const MAX_DIFFICULTY: u8 = 4;

/// The "seq" environment plugin.
pub struct SeqEnv;

impl Environment for SeqEnv {
    fn name(&self) -> &'static str {
        "seq"
    }
    fn description(&self) -> &'static str {
        "integer sequence extrapolation from a hidden generating rule"
    }
    fn max_difficulty(&self) -> u8 {
        MAX_DIFFICULTY
    }
    fn generate(&self, id: u64, difficulty: u8, rng: &mut Rng) -> Task {
        generate(id, difficulty, rng)
    }
    fn verify(&self, task: &Task, completion: &str) -> bool {
        verify(task, completion)
    }
}

/// A hidden generating rule. Serialized into the task payload and replayed
/// by the verifier.
#[derive(Clone, Debug, PartialEq)]
pub enum Rule {
    /// `a(n+1) = a(n) + step`.
    Arithmetic { start: i64, step: i64 },
    /// `a(n+1) = a(n) * ratio`.
    Geometric { start: i64, ratio: i64 },
    /// Increments alternate `+a, +b, +a, ...`.
    Alternating { start: i64, a: i64, b: i64 },
    /// `a(n+2) = a(n+1) + a(n)` from two seeds.
    SecondOrder { s0: i64, s1: i64 },
}

impl Rule {
    /// First `n` terms plus the answer term, all from the rule alone.
    pub fn terms(&self, n: usize) -> Vec<i64> {
        let mut out = Vec::with_capacity(n + 1);
        match *self {
            Rule::Arithmetic { start, step } => {
                for i in 0..=n as i64 {
                    out.push(start + step * i);
                }
            }
            Rule::Geometric { start, ratio } => {
                let mut v = start;
                for _ in 0..=n {
                    out.push(v);
                    v *= ratio;
                }
            }
            Rule::Alternating { start, a, b } => {
                let mut v = start;
                for i in 0..=n {
                    out.push(v);
                    v += if i % 2 == 0 { a } else { b };
                }
            }
            Rule::SecondOrder { s0, s1 } => {
                let (mut x, mut y) = (s0, s1);
                for _ in 0..=n {
                    out.push(x);
                    let next = x + y;
                    x = y;
                    y = next;
                }
            }
        }
        out
    }

    fn encode(&self) -> Json {
        match *self {
            Rule::Arithmetic { start, step } => Json::obj(vec![
                ("kind", "arith".into()),
                ("start", start.into()),
                ("step", step.into()),
            ]),
            Rule::Geometric { start, ratio } => Json::obj(vec![
                ("kind", "geom".into()),
                ("start", start.into()),
                ("ratio", ratio.into()),
            ]),
            Rule::Alternating { start, a, b } => Json::obj(vec![
                ("kind", "alt".into()),
                ("start", start.into()),
                ("a", a.into()),
                ("b", b.into()),
            ]),
            Rule::SecondOrder { s0, s1 } => Json::obj(vec![
                ("kind", "second".into()),
                ("s0", s0.into()),
                ("s1", s1.into()),
            ]),
        }
    }

    fn decode(j: &Json) -> Option<Rule> {
        let int = |k: &str| j.get(k).and_then(Json::as_f64).map(|v| v as i64);
        match j.get("kind")?.as_str()? {
            "arith" => Some(Rule::Arithmetic { start: int("start")?, step: int("step")? }),
            "geom" => Some(Rule::Geometric { start: int("start")?, ratio: int("ratio")? }),
            "alt" => Some(Rule::Alternating { start: int("start")?, a: int("a")?, b: int("b")? }),
            "second" => Some(Rule::SecondOrder { s0: int("s0")?, s1: int("s1")? }),
            _ => None,
        }
    }
}

/// How many sequence terms the prompt shows per difficulty.
pub fn shown_terms(difficulty: u8) -> usize {
    match difficulty {
        0 => 3,
        1 | 2 => 4,
        _ => 5,
    }
}

pub fn generate(id: u64, difficulty: u8, rng: &mut Rng) -> Task {
    let rule = match difficulty {
        0 => Rule::Arithmetic {
            start: rng.range(0, 10) as i64,
            step: 1 + rng.range(0, 5) as i64,
        },
        1 => Rule::Arithmetic {
            start: rng.range(0, 60) as i64,
            step: 2 + rng.range(0, 12) as i64,
        },
        2 => Rule::Geometric {
            start: 1 + rng.range(0, 5) as i64,
            ratio: 2 + rng.range(0, 2) as i64,
        },
        3 => Rule::Alternating {
            start: rng.range(0, 20) as i64,
            a: 1 + rng.range(0, 6) as i64,
            b: 1 + rng.range(0, 6) as i64,
        },
        _ => Rule::SecondOrder {
            s0: 1 + rng.range(0, 7) as i64,
            s1: 1 + rng.range(0, 7) as i64,
        },
    };
    let n = shown_terms(difficulty);
    let terms = rule.terms(n);
    let shown: Vec<String> = terms[..n].iter().map(|t| t.to_string()).collect();
    let prompt = format!("{},?", shown.join(","));
    Task {
        id,
        env: "seq",
        prompt,
        difficulty,
        payload: Json::obj(vec![
            ("answer", terms[n].to_string().into()),
            ("rule", rule.encode()),
            ("shown", n.into()),
        ]),
    }
}

/// Replay the hidden rule and compare against the completion's final
/// integer (same tolerant extraction as the math env: filler and a `>`
/// answer marker are fine, leading zeros count).
pub fn verify(task: &Task, completion: &str) -> bool {
    let Some(rule) = task.payload.get("rule").and_then(Rule::decode) else {
        return false;
    };
    let n = task.payload.get("shown").and_then(Json::as_usize).unwrap_or(0);
    if n == 0 {
        return false;
    }
    // swarmlint: allow(panic-path) — n == 0 returned false above, and
    // rule.terms(n) yields exactly n terms by construction.
    let want = *rule.terms(n).last().expect("terms nonempty");
    super::math::extract_answer(completion) == Some(want)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_families_extrapolate() {
        assert_eq!(Rule::Arithmetic { start: 2, step: 2 }.terms(3), vec![2, 4, 6, 8]);
        assert_eq!(Rule::Geometric { start: 3, ratio: 2 }.terms(3), vec![3, 6, 12, 24]);
        assert_eq!(
            Rule::Alternating { start: 1, a: 3, b: 2 }.terms(4),
            vec![1, 4, 6, 9, 11]
        );
        assert_eq!(Rule::SecondOrder { s0: 2, s1: 3 }.terms(4), vec![2, 3, 5, 8, 13]);
    }

    #[test]
    fn generated_tasks_verify_with_reference_answer() {
        let mut rng = Rng::new(7);
        for d in 0..=MAX_DIFFICULTY {
            for i in 0..50 {
                let t = generate(i, d, &mut rng);
                assert!(verify(&t, t.answer()), "{t:?}");
                assert!(!verify(&t, "999999999"), "{t:?}");
                // The prompt shows exactly the unshown-next-term shape.
                assert!(t.prompt.ends_with(",?"), "{t:?}");
                assert_eq!(t.prompt.matches(',').count(), shown_terms(d), "{t:?}");
            }
        }
    }

    #[test]
    fn verification_replays_the_rule_not_the_stored_answer() {
        let mut rng = Rng::new(9);
        let mut t = generate(0, 1, &mut rng);
        let honest = t.answer().to_string();
        // Tampering with the stored answer changes nothing: the verifier
        // recomputes from the rule.
        if let Json::Obj(m) = &mut t.payload {
            m.insert("answer".into(), Json::Str("123456".into()));
        }
        assert!(verify(&t, &honest));
        assert!(!verify(&t, "123456"));
        // Losing the rule makes the task unverifiable (never a free pass).
        t.payload = Json::obj(vec![("answer", honest.clone().into())]);
        assert!(!verify(&t, &honest));
    }

    #[test]
    fn tolerant_answer_extraction() {
        let mut rng = Rng::new(11);
        let t = generate(3, 0, &mut rng);
        let a = t.answer().to_string();
        assert!(verify(&t, &format!("~~ > {a}")));
        assert!(verify(&t, &format!("0{a}")) == !a.starts_with('-'));
    }
}
