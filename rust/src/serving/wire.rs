//! Wire types for serve mode: the user query the router dispatches
//! ([`ServeRequest`], JSON — it rides `TaskSpec.payload` through the
//! heartbeat flow) and the signed response a worker uploads
//! ([`ServedResponse`], binary — it rides the same HMAC-signed
//! [`Envelope`] as a rollout submission, with a TOPLOC commitment, so the
//! validator's stage 0 and the slashing path apply unchanged).

// Trust-critical parse path: hostile uploads must decode to Err, never
// panic (swarmlint `panic-path`; clippy mirrors the gate in CI).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::protocol::identity::Identity;
use crate::rl::rollout_file::Envelope;
use crate::util::json::Json;
use crate::util::wire::Cursor;

use super::serve_submission_idx;

/// Served-response wire magic ("INTELLECT-2 Served Response").
pub const SERVED_MAGIC: [u8; 4] = *b"I2SR";

/// Served-response wire version this build emits and accepts.
pub const SERVED_VERSION: u8 = 1;

/// One user query, as routed to a worker. Serialized as JSON because it
/// travels inside `TaskSpec.payload` on the heartbeat channel.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeRequest {
    /// Front-door-assigned id, unique per router lifetime; also the
    /// sampling-stream key (`serve_rng(step, query_id)`).
    pub query_id: u64,
    /// Prompt token ids (BOS-first, same alphabet as RL prompts).
    pub prompt: Vec<i32>,
    /// Completion-length cap for this query.
    pub max_new: u32,
    /// Absolute SLO deadline in milliseconds on the router's injected
    /// clock; queries past it are dropped, not served.
    pub deadline_ms: u64,
}

impl ServeRequest {
    /// Serialize for `TaskSpec.payload`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query_id", self.query_id.into()),
            (
                "prompt",
                Json::Arr(self.prompt.iter().map(|&t| Json::from(t as u32 as u64)).collect()),
            ),
            ("max_new", u64::from(self.max_new).into()),
            ("deadline_ms", self.deadline_ms.into()),
        ])
    }

    /// Parse a `TaskSpec.payload` back into a query. `None` on any
    /// structural defect — a malformed serve task is dropped, never
    /// panicked on.
    pub fn from_json(j: &Json) -> Option<ServeRequest> {
        let prompt = j
            .get("prompt")?
            .as_arr()?
            .iter()
            .map(|t| t.as_u64().map(|v| v as u32 as i32))
            .collect::<Option<Vec<i32>>>()?;
        Some(ServeRequest {
            query_id: j.get("query_id")?.as_u64()?,
            prompt,
            max_new: u32::try_from(j.get("max_new")?.as_u64()?).ok()?,
            deadline_ms: j.get("deadline_ms")?.as_u64()?,
        })
    }

    /// Tokens this query may occupy on a lane (prompt + completion cap) —
    /// what the router matches against advertised capacity.
    pub fn max_total_tokens(&self) -> u64 {
        self.prompt.len() as u64 + u64::from(self.max_new)
    }
}

/// A worker's answer to one [`ServeRequest`]: the full token sequence,
/// per-token sampling probabilities and a TOPLOC commitment — the same
/// observables a rollout carries, because the same spot-check story
/// covers both (`SamplingGate::gate_served`).
///
/// Wire layout (little-endian):
/// `"I2SR" | u8 version | u64 query_id | u64 node | u64 step |
/// u32 prompt_len | u8 finish_eos | f32 eos_prob | u32 n_tokens |
/// i32 tokens[n] | u32 n_probs | f32 probs[n] | u32 n_commit |
/// u8 commitment[n]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ServedResponse {
    pub query_id: u64,
    /// Serving node (must match the envelope's proven sender).
    pub node_address: u64,
    /// Policy version the completion was decoded under.
    pub step: u64,
    /// Prompt + completion token ids.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Model probability of each sampled completion token.
    pub sampled_probs: Vec<f32>,
    /// Encoded TOPLOC commitment over the decode's hidden rows.
    pub commitment: Vec<u8>,
    /// True if the completion terminated on EOS (else length cap).
    pub finish_eos: bool,
    /// Model probability of EOS at the terminating step.
    pub eos_prob: f32,
}

impl ServedResponse {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            4 + 1 + 3 * 8 + 4 + 1 + 4 + 4 * (self.tokens.len() + self.sampled_probs.len() + 3)
                + self.commitment.len(),
        );
        out.extend_from_slice(&SERVED_MAGIC);
        out.push(SERVED_VERSION);
        out.extend_from_slice(&self.query_id.to_le_bytes());
        out.extend_from_slice(&self.node_address.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&(self.prompt_len as u32).to_le_bytes());
        out.push(u8::from(self.finish_eos));
        out.extend_from_slice(&self.eos_prob.to_le_bytes());
        out.extend_from_slice(&(self.tokens.len() as u32).to_le_bytes());
        for t in &self.tokens {
            out.extend_from_slice(&t.to_le_bytes());
        }
        out.extend_from_slice(&(self.sampled_probs.len() as u32).to_le_bytes());
        for p in &self.sampled_probs {
            out.extend_from_slice(&p.to_le_bytes());
        }
        out.extend_from_slice(&(self.commitment.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.commitment);
        out
    }

    /// Sign + serialize for upload: the payload wrapped in a signed
    /// [`Envelope`] under `identity`'s key, with the `submission_idx`
    /// namespaced by [`super::SERVE_IDX_BIT`] so serve replays and rollout
    /// replays can never shadow each other in the `ReplayGuard`.
    pub fn encode_signed(&self, identity: &Identity) -> Vec<u8> {
        Envelope::seal(identity, self.step, serve_submission_idx(self.query_id), &self.encode())
    }

    /// Decode + structurally validate untrusted payload bytes. Everything
    /// the gate consumes downstream is made safe here: lengths are
    /// cross-checked against the buffer (a hostile header cannot force a
    /// huge allocation) and `prompt_len < tokens.len()` is enforced.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<ServedResponse> {
        let mut c = Cursor::new(bytes);
        let bad = || anyhow::anyhow!("truncated served response");
        anyhow::ensure!(
            c.array::<4>().ok_or_else(bad)? == SERVED_MAGIC,
            "not a served response (bad magic)"
        );
        anyhow::ensure!(c.u8().ok_or_else(bad)? == SERVED_VERSION, "unknown version");
        let query_id = c.u64_le().ok_or_else(bad)?;
        let node_address = c.u64_le().ok_or_else(bad)?;
        let step = c.u64_le().ok_or_else(bad)?;
        let prompt_len = c.u32_le().ok_or_else(bad)? as usize;
        let finish_eos = c.u8().ok_or_else(bad)? != 0;
        let eos_prob = c.f32_le().ok_or_else(bad)?;
        let n_tokens = c.u32_le().ok_or_else(bad)? as usize;
        anyhow::ensure!(n_tokens.saturating_mul(4) <= c.remaining(), "token count exceeds buffer");
        let mut tokens = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            tokens.push(c.u32_le().ok_or_else(bad)? as i32);
        }
        let n_probs = c.u32_le().ok_or_else(bad)? as usize;
        anyhow::ensure!(n_probs.saturating_mul(4) <= c.remaining(), "prob count exceeds buffer");
        let mut sampled_probs = Vec::with_capacity(n_probs);
        for _ in 0..n_probs {
            sampled_probs.push(c.f32_le().ok_or_else(bad)?);
        }
        let n_commit = c.u32_le().ok_or_else(bad)? as usize;
        let commitment = c.take(n_commit).ok_or_else(bad)?.to_vec();
        anyhow::ensure!(c.remaining() == 0, "trailing bytes after served response");
        anyhow::ensure!(!tokens.is_empty(), "empty served response");
        anyhow::ensure!(
            prompt_len >= 1 && prompt_len < tokens.len(),
            "prompt_len {prompt_len} outside 1..{}",
            tokens.len()
        );
        Ok(ServedResponse {
            query_id,
            node_address,
            step,
            tokens,
            prompt_len,
            sampled_probs,
            commitment,
            finish_eos,
            eos_prob,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_response() -> ServedResponse {
        ServedResponse {
            query_id: 42,
            node_address: 0xAB,
            step: 4,
            tokens: vec![1, 5, 7, 9, 2],
            prompt_len: 2,
            sampled_probs: vec![0.5, 0.25, 0.75],
            commitment: vec![1, 2, 3],
            finish_eos: true,
            eos_prob: 0.9,
        }
    }

    #[test]
    fn request_json_roundtrip() {
        let req = ServeRequest {
            query_id: 9,
            prompt: vec![1, 3, 5],
            max_new: 32,
            deadline_ms: 12_345,
        };
        assert_eq!(ServeRequest::from_json(&req.to_json()), Some(req.clone()));
        assert_eq!(req.max_total_tokens(), 35);
        // Structural defects are a clean miss.
        assert_eq!(ServeRequest::from_json(&Json::obj(vec![("query_id", 1u64.into())])), None);
        assert_eq!(ServeRequest::from_json(&Json::Null), None);
    }

    #[test]
    fn response_roundtrip_and_signed_envelope() {
        let r = sample_response();
        assert_eq!(ServedResponse::decode(&r.encode()).unwrap(), r);

        let id = Identity::from_seed(7);
        let mut signed = sample_response();
        signed.node_address = id.address;
        let bytes = signed.encode_signed(&id);
        let (env, payload) = Envelope::parse(&bytes).unwrap();
        assert_eq!(env.node_address, id.address);
        assert_eq!(env.step, signed.step);
        assert_eq!(env.submission_idx, serve_submission_idx(signed.query_id));
        assert!(env.digest_matches(payload));
        assert!(env.verify_sig(&id.secret()));
        assert_eq!(ServedResponse::decode(payload).unwrap(), signed);
    }

    #[test]
    fn hostile_response_bytes_error_out() {
        use crate::util::rng::Rng;
        let bytes = sample_response().encode();
        for cut in 0..bytes.len() {
            let _ = ServedResponse::decode(&bytes[..cut]);
        }
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            let mut b = bytes.clone();
            for _ in 0..1 + rng.usize(3) {
                let i = rng.usize(b.len());
                b[i] = b[i].wrapping_add(1 + rng.next_u32() as u8 % 255);
            }
            let _ = ServedResponse::decode(&b); // Err or Ok, never panic
        }
        // A hostile length header cannot force a huge allocation.
        let mut huge = bytes.clone();
        let n_tok_off = 4 + 1 + 24 + 4 + 1 + 4;
        huge[n_tok_off..n_tok_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ServedResponse::decode(&huge).is_err());
        // prompt_len must leave at least one completion token.
        let mut bad = sample_response();
        bad.prompt_len = bad.tokens.len();
        assert!(ServedResponse::decode(&bad.encode()).is_err());
    }
}
