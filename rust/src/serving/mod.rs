//! Serve mode: user-traffic inference on the RL swarm (the ROADMAP's
//! "second workload"). The same fleet that generates RL rollouts answers
//! user queries co-tenant on the continuous-batching scheduler — lloom's
//! client → executor → validator shape, carried by the protocol layer we
//! already trust for rollouts.
//!
//! # Topology
//!
//! - **Front door** — user queries ([`ServeRequest`]) enter through the
//!   orchestrator's `POST /query` route and land in the [`ServeRouter`]'s
//!   FIFO. No new transport: assignment rides the existing heartbeat /
//!   `TaskSpec` pull flow as `kind = "serve"` tasks, handed out *ahead of*
//!   the regular task queue.
//! - **Capacity advertisement** — workers annotate each heartbeat with
//!   their serving capacity ([`ServeCapacity`]: free decode lanes,
//!   supported max tokens). The router only assigns a query to a node
//!   whose advertised capacity covers `prompt + max_new`; nodes that
//!   advertise nothing serve nothing and behave exactly as before.
//! - **Priority refill** — on the worker, the query joins the next
//!   generation batch with its priority flag set
//!   (`runtime::scheduler::run_continuous_prioritized`), so it takes the
//!   next free decode lane ahead of pending RL prompts. Decode ticks are
//!   shared; only *lane admission order* changes, and per-rollout RNG
//!   streams keep every RL rollout's wire output byte-identical under
//!   co-tenancy.
//! - **Trust** — a served response ([`ServedResponse`]) travels in the
//!   same HMAC-signed [`crate::rl::rollout_file::Envelope`] as a rollout
//!   submission, carries a TOPLOC commitment, and is spot-checked by the
//!   validator through the `SamplingGate` (`coordinator::validation`):
//!   completions are deterministic in `(step, query_id)` via
//!   [`serve_rng`], so a sampled check recomputes the completion and a
//!   forged response slashes its signer. Replay protection shares the
//!   rollout `ReplayGuard` keyspace through [`serve_submission_idx`].
//!
//! # SLO clock
//!
//! Deadline math never reads ambient wall-clock time (swarmlint rule R2
//! binds on this module): every router method takes an explicit
//! `now_ms`, and hosts inject a [`SloClock`] at the orchestrator — real
//! time in production, a deterministic counter in tests.

pub mod router;
pub mod wire;

pub use router::{ServeCapacity, ServeRouter};
pub use wire::{ServeRequest, ServedResponse, SERVED_MAGIC};

use crate::util::rng::Rng;

/// Injected time source for deadline/SLO math, in milliseconds from an
/// epoch the host chooses (R2: trust modules never read wall-clock time
/// ambiently). The orchestrator defaults to real time and tests inject
/// deterministic ticks.
pub type SloClock = std::sync::Arc<dyn Fn() -> u64 + Send + Sync>;

/// `TaskSpec.kind` of a routed serve task on the heartbeat channel.
pub const SERVE_TASK_KIND: &str = "serve";

/// High bit namespacing served responses inside the envelope
/// `submission_idx` field: rollout submissions count 0, 1, 2, … per
/// node/step, so serve envelopes live in the disjoint upper half and a
/// replayed served response can never collide with (or shadow) a rollout
/// submission in the validator's `ReplayGuard`.
pub const SERVE_IDX_BIT: u64 = 1 << 63;

/// Envelope `submission_idx` for a served query (see [`SERVE_IDX_BIT`]).
pub fn serve_submission_idx(query_id: u64) -> u64 {
    SERVE_IDX_BIT | (query_id & !SERVE_IDX_BIT)
}

/// Domain separator for serve-mode sampling streams (distinct from the
/// rollout `gen_seed` domain, so a query can never alias an RL rollout's
/// stream).
const SERVE_RNG_DOMAIN: u64 = 0x5E7E_F00D;

/// The sampling stream for serving `query_id` at policy `step`:
/// deterministic in public response fields only, so a validator — or any
/// auditor — recomputes a served completion without knowing which worker
/// served it or how its scheduler packed the lanes (the same §2.3.3
/// fixed-sampling property rollouts have).
pub fn serve_rng(step: u64, query_id: u64) -> Rng {
    Rng::new(step ^ SERVE_RNG_DOMAIN).fold(query_id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_rng_streams_are_distinct_and_stable() {
        assert_eq!(serve_rng(3, 7).next_u64(), serve_rng(3, 7).next_u64());
        assert_ne!(serve_rng(3, 7).next_u64(), serve_rng(3, 8).next_u64());
        assert_ne!(serve_rng(3, 7).next_u64(), serve_rng(4, 7).next_u64());
        // Never aliases a rollout stream of the same numerology.
        assert_ne!(
            serve_rng(3, 7).next_u64(),
            crate::runtime::scheduler::rollout_rng(3, 7).next_u64()
        );
    }

    #[test]
    fn serve_idx_is_namespaced() {
        assert_eq!(serve_submission_idx(0), SERVE_IDX_BIT);
        assert_eq!(serve_submission_idx(5) & !SERVE_IDX_BIT, 5);
        // Rollout submission indices are small; the bit keeps the spaces
        // disjoint even for adversarially-large query ids.
        assert_eq!(serve_submission_idx(SERVE_IDX_BIT | 5), SERVE_IDX_BIT | 5);
        assert_ne!(serve_submission_idx(3), 3);
    }
}
