//! Front-door request router: queues user queries, matches them against
//! worker-advertised serving capacity, and tracks per-query deadlines on
//! the injected SLO clock. The orchestrator owns one of these inside its
//! state lock and drains it *ahead of* the regular task queue at
//! heartbeat time, so a pending user query preempts pending RL work.
//!
//! Deadline math takes `now` explicitly everywhere (R2: no ambient
//! clock reads in trust modules), and iteration is deterministic
//! (`VecDeque` / `BTreeMap` only — R1): replaying the same heartbeat
//! order against the same clock yields the same assignments.

// Hostile/absent state must surface as None/0, never as a panic
// (swarmlint `panic-path`; clippy mirrors the gate in CI).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use std::collections::{BTreeMap, VecDeque};

use crate::util::metrics::Counter;

use super::wire::ServeRequest;

/// Per-node serving capacity, advertised on every heartbeat: how many
/// decode lanes the node keeps free for user traffic and the longest
/// `prompt + max_new` it will take. A node that never advertises is not
/// a serving node and the router never assigns to it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeCapacity {
    /// Decode lanes currently available for serve traffic.
    pub free_lanes: u32,
    /// Longest total sequence (prompt + completion) the node supports.
    pub max_tokens: u32,
}

/// A query assigned to a node, awaiting its completion report.
#[derive(Clone, Debug)]
struct InFlight {
    node: u64,
    request: ServeRequest,
}

/// FIFO query router with capacity matching and deadline accounting.
/// All mutation is driven by the orchestrator under its state lock; every
/// method takes the SLO clock's current reading explicitly.
#[derive(Default)]
pub struct ServeRouter {
    queue: VecDeque<ServeRequest>,
    in_flight: BTreeMap<u64, InFlight>,
    capacity: BTreeMap<u64, ServeCapacity>,
    next_query_id: u64,
    /// Queries accepted at the front door.
    pub queries_submitted: Counter,
    /// Assignments handed to workers (requeues count again).
    pub queries_assigned: Counter,
    /// Completions reported back, on time or not.
    pub queries_completed: Counter,
    /// Completions that arrived after their deadline.
    pub deadlines_missed: Counter,
    /// Queries dropped because their deadline passed before completion
    /// (in queue, or orphaned past recovery).
    pub queries_expired: Counter,
    /// Orphaned queries re-entered at the queue front after their holder
    /// was evicted or slashed.
    pub queries_requeued: Counter,
}

impl ServeRouter {
    /// Allocate a router-unique query id for a front-door request.
    pub fn next_query_id(&mut self) -> u64 {
        let id = self.next_query_id;
        self.next_query_id += 1;
        id
    }

    /// Accept a query at the front door. Returns `false` (and counts an
    /// expiry) if the deadline already passed — an unserviceable query is
    /// refused immediately rather than queued to fail.
    pub fn submit(&mut self, request: ServeRequest, now: u64) -> bool {
        if now >= request.deadline_ms {
            self.queries_expired.inc();
            return false;
        }
        self.queries_submitted.inc();
        self.queue.push_back(request);
        true
    }

    /// Record `node`'s latest advertised capacity.
    pub fn advertise(&mut self, node: u64, capacity: ServeCapacity) {
        self.capacity.insert(node, capacity);
    }

    /// `node`'s last advertised capacity, if any.
    pub fn capacity_of(&self, node: u64) -> Option<ServeCapacity> {
        self.capacity.get(&node).copied()
    }

    /// Drop `node` from the capacity table (evicted/slashed nodes must
    /// not look assignable on stale advertisements).
    pub fn forget(&mut self, node: u64) {
        self.capacity.remove(&node);
    }

    /// Hand `node` the first queued query its advertised capacity covers,
    /// dropping dead queries (deadline passed) encountered on the way.
    /// FIFO across the queue; a query no live node can cover stays queued
    /// until it expires rather than starving younger ones behind it.
    pub fn assign(&mut self, node: u64, now: u64) -> Option<ServeRequest> {
        let cap = self.capacity.get(&node).copied()?;
        if cap.free_lanes == 0 {
            return None;
        }
        let mut picked: Option<usize> = None;
        let mut i = 0;
        while i < self.queue.len() {
            let Some(req) = self.queue.get(i) else { break };
            if now >= req.deadline_ms {
                self.queue.remove(i);
                self.queries_expired.inc();
                continue; // same index now holds the next query
            }
            if req.max_total_tokens() <= u64::from(cap.max_tokens) {
                picked = Some(i);
                break;
            }
            i += 1;
        }
        let request = self.queue.remove(picked?)?;
        self.queries_assigned.inc();
        self.in_flight.insert(request.query_id, InFlight { node, request: request.clone() });
        Some(request)
    }

    /// A worker reported `query_id` answered. Returns whether the answer
    /// landed within its deadline (`None`: unknown query — already
    /// expired, requeued, or never assigned).
    pub fn complete(&mut self, query_id: u64, now: u64) -> Option<bool> {
        let inf = self.in_flight.remove(&query_id)?;
        self.queries_completed.inc();
        let on_time = now <= inf.request.deadline_ms;
        if !on_time {
            self.deadlines_missed.inc();
        }
        Some(on_time)
    }

    /// Recover every query `node` was holding (eviction/slash path):
    /// still-live queries re-enter at the queue *front* — they have been
    /// waiting longest — and dead ones are dropped as expired. Also
    /// forgets the node's capacity. Returns how many were requeued.
    pub fn requeue_node(&mut self, node: u64, now: u64) -> u64 {
        self.forget(node);
        let orphaned: Vec<u64> = self
            .in_flight
            .iter()
            .filter(|(_, inf)| inf.node == node)
            .map(|(&id, _)| id)
            .collect();
        let mut requeued = 0;
        // Reverse order keeps front-pushed orphans in their original
        // relative order (the same `.rev()` idiom as task requeue).
        for id in orphaned.into_iter().rev() {
            let Some(inf) = self.in_flight.remove(&id) else { continue };
            if now >= inf.request.deadline_ms {
                self.queries_expired.inc();
            } else {
                self.queue.push_front(inf.request);
                self.queries_requeued.inc();
                requeued += 1;
            }
        }
        requeued
    }

    /// Queries waiting for assignment.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Queries assigned and not yet completed.
    pub fn assigned(&self) -> usize {
        self.in_flight.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize, max_new: u32, deadline_ms: u64) -> ServeRequest {
        ServeRequest { query_id: id, prompt: vec![1; plen], max_new, deadline_ms }
    }

    #[test]
    fn fifo_assignment_respects_capacity() {
        let mut r = ServeRouter::default();
        assert!(r.submit(req(0, 4, 100, 1000), 0)); // needs 104 tokens
        assert!(r.submit(req(1, 4, 8, 1000), 0)); // needs 12
        // No capacity advertised: nothing to assign.
        assert_eq!(r.assign(7, 10), None);
        // Small node skips the big query but serves the small one (FIFO
        // among coverable queries).
        r.advertise(7, ServeCapacity { free_lanes: 1, max_tokens: 64 });
        assert_eq!(r.assign(7, 10).map(|q| q.query_id), Some(1));
        // Big node picks up the head-of-line query.
        r.advertise(8, ServeCapacity { free_lanes: 2, max_tokens: 256 });
        assert_eq!(r.assign(8, 10).map(|q| q.query_id), Some(0));
        assert_eq!(r.pending(), 0);
        assert_eq!(r.assigned(), 2);
        // Zero advertised lanes = not assignable.
        r.submit(req(2, 4, 8, 1000), 0);
        r.advertise(9, ServeCapacity { free_lanes: 0, max_tokens: 256 });
        assert_eq!(r.assign(9, 10), None);
    }

    #[test]
    fn deadlines_expire_and_complete_on_time_or_late() {
        let mut r = ServeRouter::default();
        // Already dead at the front door: refused.
        assert!(!r.submit(req(0, 2, 4, 100), 100));
        assert_eq!(r.queries_expired.get(), 1);
        // Dies in queue: dropped at assignment time.
        assert!(r.submit(req(1, 2, 4, 200), 0));
        assert!(r.submit(req(2, 2, 4, 900), 0));
        r.advertise(7, ServeCapacity { free_lanes: 1, max_tokens: 64 });
        assert_eq!(r.assign(7, 500).map(|q| q.query_id), Some(2));
        assert_eq!(r.queries_expired.get(), 2);
        // On-time and late completions are told apart.
        assert_eq!(r.complete(2, 899), Some(true));
        r.submit(req(3, 2, 4, 1000), 950);
        r.assign(7, 960).unwrap();
        assert_eq!(r.complete(3, 2000), Some(false));
        assert_eq!(r.deadlines_missed.get(), 1);
        // Unknown query: None.
        assert_eq!(r.complete(99, 0), None);
    }

    #[test]
    fn requeue_recovers_orphans_in_order_and_drops_dead_ones() {
        let mut r = ServeRouter::default();
        r.advertise(7, ServeCapacity { free_lanes: 4, max_tokens: 64 });
        for id in 0..3 {
            r.submit(req(id, 2, 4, if id == 1 { 50 } else { 1000 }), 0);
            r.assign(7, 10).unwrap();
        }
        assert_eq!(r.assigned(), 3);
        // Node dies at t=100: query 1's deadline (50) has passed.
        assert_eq!(r.requeue_node(7, 100), 2);
        assert_eq!(r.queries_expired.get(), 1);
        assert_eq!(r.assigned(), 0);
        // Orphans re-enter at the front in their original order, and the
        // dead node's capacity is forgotten.
        assert_eq!(r.capacity_of(7), None);
        assert_eq!(r.assign(7, 100), None);
        r.advertise(8, ServeCapacity { free_lanes: 4, max_tokens: 64 });
        assert_eq!(r.assign(8, 100).map(|q| q.query_id), Some(0));
        assert_eq!(r.assign(8, 100).map(|q| q.query_id), Some(2));
        // Requeue of a node holding nothing is a no-op.
        assert_eq!(r.requeue_node(9, 100), 0);
    }

    #[test]
    fn query_ids_are_unique() {
        let mut r = ServeRouter::default();
        assert_eq!(r.next_query_id(), 0);
        assert_eq!(r.next_query_id(), 1);
        assert_eq!(r.next_query_id(), 2);
    }
}
