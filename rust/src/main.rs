//! `intellect2` launcher — the leader entrypoint.
//!
//!   intellect2 train  [--model nano --rl-steps 20 ...]   deterministic async-k pipeline
//!   intellect2 swarm  [--workers 3 --relays 2 ...]       full decentralized swarm (HTTP)
//!   intellect2 eval   [--model nano --eval-n 24]         held-out suite evaluation
//!   intellect2 info   [--model nano]                     artifact/spec inspection
//!
//! Any `RunConfig` field can be overridden with `--key value` (see
//! config::RunConfig::apply_args); `--config path` loads `key = value`
//! lines first.

use std::sync::Arc;

use intellect2::config::RunConfig;
use intellect2::coordinator::{Swarm, SyncPipeline};
use intellect2::util::cli::Args;
use intellect2::util::metrics::sparkline;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    cfg = cfg.apply_args(&args);

    match cmd {
        "train" => {
            let pipeline = SyncPipeline::new(cfg.clone())?;
            let state = pipeline.bootstrap()?;
            pipeline.run_rl(state, cfg.rl_steps, "", false)?;
            let reward: Vec<f64> =
                pipeline.series.get("task_reward").iter().map(|x| x.1).collect();
            println!(
                "task reward {}  {:.3} -> {:.3}",
                sparkline(&reward),
                reward.first().unwrap_or(&0.0),
                reward.last().unwrap_or(&0.0)
            );
            let out = args.str_or("out", "runs/train.jsonl");
            pipeline.series.save(&out)?;
            println!("series written to {out}");
        }
        "swarm" => {
            let swarm = Swarm::new(cfg.clone())?;
            let result = swarm.run(cfg.pretrain_steps, args.has_flag("evil-worker"))?;
            println!(
                "done: {} rollouts verified, {} submissions rejected, {} nodes slashed",
                result.stats.rollouts_verified.get(),
                result.stats.submissions_rejected.get(),
                result.stats.nodes_slashed.get()
            );
            let out = args.str_or("out", "runs/swarm.jsonl");
            result.series.save(&out)?;
            println!("series written to {out}");
        }
        "eval" => {
            let pipeline = SyncPipeline::new(cfg.clone())?;
            let state = pipeline.bootstrap()?;
            let params = Arc::new(state.params.clone());
            let n = args.usize_or("eval-n", 24);
            for suite in intellect2::tasks::eval::Suite::standard(pipeline.registry()) {
                let score = pipeline.evaluate_suite(&params, &suite, n)?;
                println!("{:<40} {score:.1}%", suite.name());
            }
        }
        "info" => {
            let host = intellect2::runtime::EngineHost::spawn_size(&cfg.model)?;
            let spec = host.spec();
            println!("model {}: {} params", spec.name, spec.n_params);
            println!(
                "  d_model {} | layers {} | heads {} | ctx {} | vocab {}",
                spec.d_model, spec.n_layers, spec.n_heads, spec.max_seq, spec.vocab
            );
            println!("  batch: train {} / infer {}", spec.batch_train, spec.batch_infer);
            println!("  artifacts:");
            for (name, meta) in &spec.artifacts {
                println!(
                    "    {name:<20} {} inputs, {} outputs ({})",
                    meta.inputs.len(),
                    meta.outputs.len(),
                    meta.file
                );
            }
        }
        _ => {
            println!("usage: intellect2 <train|swarm|eval|info> [--key value ...]");
            println!("see README.md for the full flag reference");
        }
    }
    Ok(())
}
