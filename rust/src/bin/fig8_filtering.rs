//! Fig 8: reward trajectories with vs without offline difficulty
//! filtering (§3.3.1). The unfiltered dataset (dominated by too-easy /
//! too-hard tasks) stagnates; filtering to the base model's pass@8 band
//! [1, 4] climbs.
//!
//!   cargo run --release --bin fig8_filtering -- --rl-steps 12

use std::sync::Arc;

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::rl::filtering::FilterBand;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{render_table, sparkline, Series};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // Deliberately easy+hard-heavy dataset so unfiltered training stalls:
    // most easy tasks are degenerate (all-correct groups), most hard ones
    // all-wrong.
    let cfg = RunConfig {
        rl_steps: 10,
        pretrain_steps: 100,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 14,
        env_mix: intellect2::tasks::dataset::EnvMix::of(&[("math", 300)]),
        ..Default::default()
    }
    .apply_args(&args);

    println!("== Fig 8: offline pass@8 difficulty filtering ==");
    let out = Series::default();
    let mut rows = Vec::new();

    // Shared base model for both arms.
    let pipeline = SyncPipeline::new(cfg.clone())?;
    let base_state = pipeline.bootstrap()?;
    let base_params = Arc::new(base_state.params.clone());

    // Pass@8 estimation with the base model (the paper uses the distilled
    // 7B as the estimator; we use the base policy itself).
    let k = 8;
    let stats = pipeline.estimate_pass_at_k(&base_params, k, pipeline.dataset.len().min(120))?;
    let band = FilterBand::default();
    let keep = stats.keep(&band);
    let (easy, mid, hard) = stats.band_fractions(&band);
    println!(
        "pass@{k} over {} tasks: {:.0}% too easy, {:.0}% in band, {:.0}% too hard -> keeping {}",
        stats.per_task.len(),
        100.0 * easy,
        100.0 * mid,
        100.0 * hard,
        keep.len()
    );
    for (env, kept, total) in stats.by_env(&band) {
        println!("  [{env}] kept {kept}/{total}");
    }

    for (label, filtered) in [("unfiltered", false), ("filtered", true)] {
        let mut p = SyncPipeline::new(cfg.clone())?;
        if filtered {
            if keep.len() < cfg.prompts_per_step {
                println!("(band too small; widening to [1, 6])");
                let wide = stats.keep(&FilterBand { k, min_pass: 1, max_pass: 6 });
                p.set_dataset(p.dataset.filtered(&wide))?;
            } else {
                p.set_dataset(p.dataset.filtered(&keep))?;
            }
        }
        // Same base weights.
        let state = Box::new(intellect2::runtime::HostTrainState {
            params: base_state.params.clone(),
            m: base_state.m.clone(),
            v: base_state.v.clone(),
            step: 0,
        });
        p.run_rl(state, cfg.rl_steps, "", false)?;
        let xs: Vec<f64> = p.series.smoothed("task_reward", 3).iter().map(|x| x.1).collect();
        let gain = xs.last().unwrap_or(&0.0) - xs.first().unwrap_or(&0.0);
        for (i, v) in xs.iter().enumerate() {
            out.push(i as u64, &format!("{label}_task_reward"), *v);
        }
        rows.push(vec![
            label.to_string(),
            format!("{:.3}", xs.first().unwrap_or(&0.0)),
            format!("{:.3}", xs.last().unwrap_or(&0.0)),
            format!("{gain:+.3}"),
            sparkline(&xs),
        ]);
    }
    println!("{}", render_table(&["dataset", "reward@0", "reward@end", "gain", "trajectory"], &rows));
    out.save("runs/fig8_filtering.jsonl")?;
    println!("series written to runs/fig8_filtering.jsonl");
    Ok(())
}
