//! Bench-regression comparator for CI: diff the previous run's
//! `BENCH_*.json` artifacts against the current run's and warn (GitHub
//! `::warning::` annotations) when a higher-is-better figure drops past
//! the threshold. Advisory by default — the perf trajectory should gate
//! merges only once the runners are stable enough to trust; pass
//! `--fail-on-regression` to make it binding.
//!
//!   bench_compare --old prev-bench/ --new . [--threshold-pct 15]
//!                 [--fail-on-regression]

use std::path::Path;

use intellect2::util::bench::compare_bench_docs;
use intellect2::util::cli::Args;
use intellect2::util::json::Json;

fn load_bench_docs(dir: &str) -> Vec<(String, Json)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        if !name.starts_with("BENCH_") || !name.ends_with(".json") {
            continue;
        }
        let Ok(text) = std::fs::read_to_string(entry.path()) else {
            continue;
        };
        match Json::parse(&text) {
            Ok(doc) => out.push((name, doc)),
            Err(e) => eprintln!("::warning::{name}: unparseable bench JSON ({e})"),
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

fn main() -> std::process::ExitCode {
    let args = Args::from_env();
    let old_dir = args.str_or("old", "prev-bench");
    let new_dir = args.str_or("new", ".");
    let threshold = args.f64_or("threshold-pct", 15.0) / 100.0;
    let binding = args.has_flag("fail-on-regression");

    if !Path::new(&old_dir).is_dir() {
        // First run on a branch, expired artifacts, or history disabled:
        // nothing to compare against is not a failure.
        println!("bench_compare: no baseline directory {old_dir:?}; skipping comparison");
        return std::process::ExitCode::SUCCESS;
    }
    let old = load_bench_docs(&old_dir);
    let new = load_bench_docs(&new_dir);
    if old.is_empty() || new.is_empty() {
        println!(
            "bench_compare: nothing to compare (old: {} files, new: {} files)",
            old.len(),
            new.len()
        );
        return std::process::ExitCode::SUCCESS;
    }

    let mut regressions = 0usize;
    for (name, new_doc) in &new {
        let Some((_, old_doc)) = old.iter().find(|(n, _)| n == name) else {
            println!("{name}: no baseline (new bench)");
            continue;
        };
        for d in compare_bench_docs(old_doc, new_doc) {
            let pct = d.delta_frac * 100.0;
            println!("{name}: {:<40} {:>12.2} -> {:>12.2}  ({pct:+.1}%)", d.key, d.old, d.new);
            if d.regressed(threshold) {
                regressions += 1;
                let direction = if d.lower_is_better { "rose" } else { "dropped" };
                println!(
                    "::warning::bench regression in {name}: {} {direction} {:.1}% \
                     ({:.2} -> {:.2}, threshold {:.0}%)",
                    d.key,
                    pct.abs(),
                    d.old,
                    d.new,
                    threshold * 100.0
                );
            }
        }
    }
    if regressions > 0 && binding {
        eprintln!("bench_compare: {regressions} regression(s) past threshold");
        return std::process::ExitCode::FAILURE;
    }
    std::process::ExitCode::SUCCESS
}
