//! Fig 6: synchronous vs one-step vs two-step asynchronous RL timelines.
//! A deterministic pipeline-timeline simulation: given stage durations
//! (inference, broadcast, verify, train) it lays out the schedule for each
//! mode and reports trainer/inference utilization — showing how two-step
//! asynchrony hides the weight-broadcast entirely (the paper's "no
//! communication overhead" claim).
//!
//!   cargo run --release --bin fig6_async_overlap -- --steps 8

use intellect2::util::cli::Args;
use intellect2::util::metrics::render_table;

#[derive(Clone, Copy)]
struct Durations {
    inference: f64,
    broadcast: f64,
    verify: f64,
    train: f64,
}

/// Returns (makespan, trainer_busy, inference_busy) for `n` RL steps.
fn simulate(mode: u64, n: u64, d: Durations) -> (f64, f64, f64) {
    let mut trainer_busy = 0.0;
    let mut inference_busy = 0.0;
    let mut t = 0.0f64;
    match mode {
        // Synchronous: same GPUs alternate inference and training; the
        // broadcast is a local weight swap (free) but nothing overlaps.
        0 => {
            for _ in 0..n {
                t += d.inference + d.verify;
                inference_busy += d.inference;
                t += d.train;
                trainer_busy += d.train;
            }
        }
        // One-step async (centralized): inference for step s+1 runs during
        // training of step s; broadcast is instant (same cluster), so each
        // step costs max(inference+verify, train).
        1 => {
            for _ in 0..n {
                let stage = (d.inference + d.verify).max(d.train);
                t += stage;
                inference_busy += d.inference;
                trainer_busy += d.train;
            }
        }
        // Two-step async (decentralized): the broadcast also overlaps —
        // workers keep generating with weights from s-2 while s-1 is still
        // propagating. Step cost: max(inference+verify, train, broadcast).
        _ => {
            for _ in 0..n {
                let stage = (d.inference + d.verify).max(d.train).max(d.broadcast);
                t += stage;
                inference_busy += d.inference;
                trainer_busy += d.train;
            }
        }
    }
    (t, trainer_busy, inference_busy)
}

fn main() {
    let args = Args::from_env();
    let n = args.u64_or("steps", 8);
    // Default stage durations follow the paper's §4.2 TARGET-SHORT
    // accounting: broadcast 14 min, ~10 min generation + ~1 min verify,
    // ~22 min train (normalized to train = 22).
    let d = Durations {
        inference: args.f64_or("inference", 10.0),
        broadcast: args.f64_or("broadcast", 14.0),
        verify: args.f64_or("verify", 1.0),
        train: args.f64_or("train", 22.0),
    };

    println!("== Fig 6: sync vs 1-step vs 2-step async pipeline timelines ==");
    println!(
        "stage durations (min): inference {} | broadcast {} | verify {} | train {}\n",
        d.inference, d.broadcast, d.verify, d.train
    );
    let mut rows = Vec::new();
    for (mode, label) in [
        (0, "synchronous"),
        (1, "1-step async (centralized)"),
        (2, "2-step async (decentralized)"),
    ] {
        // Sync pays broadcast=0 (co-located); async-1 pays it serially in a
        // decentralized deployment — model that too for the comparison.
        let (makespan, tr, inf) = if mode == 1 {
            // decentralized 1-step: broadcast blocks the next inference.
            let mut t = 0.0;
            for _ in 0..n {
                t += (d.inference + d.verify + d.broadcast).max(d.train);
            }
            (t, n as f64 * d.train, n as f64 * d.inference)
        } else {
            simulate(mode, n, d)
        };
        rows.push(vec![
            label.to_string(),
            format!("{makespan:.0} min"),
            format!("{:.0}%", 100.0 * tr / makespan),
            format!("{:.0}%", 100.0 * inf / makespan),
            format!("{:.2} min/step", makespan / n as f64),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["mode", "makespan", "trainer util", "inference util", "step time"],
            &rows
        )
    );
    println!(
        "(2-step async hides the {} min broadcast completely: step time == max(stage) — \
         the paper reports near-perfect overlap in §4.2)",
        d.broadcast
    );
}
