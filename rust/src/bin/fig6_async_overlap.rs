//! Fig 6: synchronous vs one-step vs two-step asynchronous RL timelines.
//! A deterministic pipeline-timeline simulation: given stage durations
//! (inference, broadcast, verify, train) it lays out the schedule for each
//! mode and reports trainer/inference utilization — showing how two-step
//! asynchrony hides the weight-broadcast entirely (the paper's "no
//! communication overhead" claim).
//!
//!   cargo run --release --bin fig6_async_overlap -- --steps 8
//!
//! With `--real`, it also runs the actual threaded swarm (requires
//! `make artifacts`) with a shaped origin uplink and prints the *measured*
//! pipeline — broadcast duration, how much of it was hidden behind the
//! next step's training, and the off-policy staleness histogram — next to
//! the analytic prediction:
//!
//!   cargo run --release --bin fig6_async_overlap -- --real --rl-steps 3

use intellect2::config::RunConfig;
use intellect2::coordinator::Swarm;
use intellect2::util::cli::Args;
use intellect2::util::metrics::render_table;

#[derive(Clone, Copy)]
struct Durations {
    inference: f64,
    broadcast: f64,
    verify: f64,
    train: f64,
}

/// Returns (makespan, trainer_busy, inference_busy) for `n` RL steps.
fn simulate(mode: u64, n: u64, d: Durations) -> (f64, f64, f64) {
    let mut trainer_busy = 0.0;
    let mut inference_busy = 0.0;
    let mut t = 0.0f64;
    match mode {
        // Synchronous: same GPUs alternate inference and training; the
        // broadcast is a local weight swap (free) but nothing overlaps.
        0 => {
            for _ in 0..n {
                t += d.inference + d.verify;
                inference_busy += d.inference;
                t += d.train;
                trainer_busy += d.train;
            }
        }
        // One-step async (centralized): inference for step s+1 runs during
        // training of step s; broadcast is instant (same cluster), so each
        // step costs max(inference+verify, train).
        1 => {
            for _ in 0..n {
                let stage = (d.inference + d.verify).max(d.train);
                t += stage;
                inference_busy += d.inference;
                trainer_busy += d.train;
            }
        }
        // Two-step async (decentralized): the broadcast also overlaps —
        // workers keep generating with weights from s-2 while s-1 is still
        // propagating. Step cost: max(inference+verify, train, broadcast).
        _ => {
            for _ in 0..n {
                let stage = (d.inference + d.verify).max(d.train).max(d.broadcast);
                t += stage;
                inference_busy += d.inference;
                trainer_busy += d.train;
            }
        }
    }
    (t, trainer_busy, inference_busy)
}

/// Run the real swarm and print measured pipeline overlap (vs the
/// simulation above, which only *predicts* it).
fn real_pipeline(args: &Args) -> anyhow::Result<()> {
    let cfg = RunConfig {
        rl_steps: 3,
        prompts_per_step: 2,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 12,
        pretrain_steps: 40,
        n_workers: 2,
        n_relays: 2,
        // Shape the origin uplink so the broadcast takes real wall-clock,
        // like the paper's WAN links — the overlap has to earn its keep.
        origin_egress_bps: args.u64_or("origin-egress-bps", 200_000),
        broadcast_timeout_secs: 60,
        ..Default::default()
    }
    .apply_args(args);

    println!("\n== measured two-step-async pipeline (real swarm) ==");
    let swarm = Swarm::new(cfg.clone())?;
    let result = swarm.run(cfg.pretrain_steps, false)?;

    println!(
        "{}",
        render_table(
            &["step", "broadcast_s", "batch_ready_s", "train_s", "overlap_s"],
            &result.timing_rows()
        )
    );
    println!(
        "staleness of trained rollouts (window k={}): {} | dropped stale: {}",
        cfg.async_level,
        result.stats.staleness_summary(),
        result.stats.rollouts_dropped_stale.get()
    );
    println!(
        "(a synchronous trainer would add the full broadcast_s column to every \
         step; overlap_s shows how much of it the pipelined trainer hid)"
    );
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let n = args.u64_or("steps", 8);
    // Default stage durations follow the paper's §4.2 TARGET-SHORT
    // accounting: broadcast 14 min, ~10 min generation + ~1 min verify,
    // ~22 min train (normalized to train = 22).
    let d = Durations {
        inference: args.f64_or("inference", 10.0),
        broadcast: args.f64_or("broadcast", 14.0),
        verify: args.f64_or("verify", 1.0),
        train: args.f64_or("train", 22.0),
    };

    println!("== Fig 6: sync vs 1-step vs 2-step async pipeline timelines ==");
    println!(
        "stage durations (min): inference {} | broadcast {} | verify {} | train {}\n",
        d.inference, d.broadcast, d.verify, d.train
    );
    let mut rows = Vec::new();
    for (mode, label) in [
        (0, "synchronous"),
        (1, "1-step async (centralized)"),
        (2, "2-step async (decentralized)"),
    ] {
        // Sync pays broadcast=0 (co-located); async-1 pays it serially in a
        // decentralized deployment — model that too for the comparison.
        let (makespan, tr, inf) = if mode == 1 {
            // decentralized 1-step: broadcast blocks the next inference.
            let mut t = 0.0;
            for _ in 0..n {
                t += (d.inference + d.verify + d.broadcast).max(d.train);
            }
            (t, n as f64 * d.train, n as f64 * d.inference)
        } else {
            simulate(mode, n, d)
        };
        rows.push(vec![
            label.to_string(),
            format!("{makespan:.0} min"),
            format!("{:.0}%", 100.0 * tr / makespan),
            format!("{:.0}%", 100.0 * inf / makespan),
            format!("{:.2} min/step", makespan / n as f64),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["mode", "makespan", "trainer util", "inference util", "step time"],
            &rows
        )
    );
    println!(
        "(2-step async hides the {} min broadcast completely: step time == max(stage) — \
         the paper reports near-perfect overlap in §4.2)",
        d.broadcast
    );

    if args.has_flag("real") {
        if let Err(e) = real_pipeline(&args) {
            eprintln!("real pipeline failed (run `make artifacts` first?): {e}");
            std::process::exit(1);
        }
    }
}
