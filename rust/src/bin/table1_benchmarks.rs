//! Table 1: benchmark comparison. Paper: INTELLECT-2 vs QwQ-32B (its base)
//! on AIME24/25, LiveCodeBench, GPQA-Diamond, IFEval. Here: RL-trained
//! model vs its pretrained base on the five suite analogues — the shape to
//! reproduce is "RL improves math+code, instruction-following may dip
//! slightly" (the paper trains only on math/code).
//!
//!   cargo run --release --bin table1_benchmarks -- --rl-steps 12 --eval-n 24

use std::sync::Arc;

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::rl::reward::RewardConfig;
use intellect2::tasks::eval::Suite;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{render_table, Series};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let eval_n = args.usize_or("eval-n", 24);
    let cfg = RunConfig {
        rl_steps: 12,
        pretrain_steps: 120,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 48,
        reward: RewardConfig::target_short(),
        ..Default::default()
    }
    .apply_args(&args);

    println!("== Table 1: held-out benchmark suites, base vs RL-trained ==");
    let pipeline = SyncPipeline::new(cfg.clone())?;
    let base_state = pipeline.bootstrap()?;
    let base = Arc::new(base_state.params.clone());
    let tuned_state = pipeline.run_rl(base_state, cfg.rl_steps, "", false)?;
    let tuned = Arc::new(tuned_state.params.clone());

    let out = Series::default();
    let mut rows = Vec::new();
    // The five classic analogues plus every registered env's derived
    // held-out suite (plug in an env, it shows up here automatically).
    for suite in Suite::standard(pipeline.registry()) {
        let b = pipeline.evaluate_suite(&base, &suite, eval_n)?;
        let t = pipeline.evaluate_suite(&tuned, &suite, eval_n)?;
        out.push(0, &format!("base {}", suite.name()), b);
        out.push(0, &format!("tuned {}", suite.name()), t);
        rows.push(vec![
            suite.name().to_string(),
            format!("{b:.1}"),
            format!("{t:.1}"),
            format!("{:+.1}", t - b),
        ]);
    }
    println!(
        "{}",
        render_table(&["suite", "base model", "INTELLECT-2 (RL)", "delta"], &rows)
    );
    println!(
        "(paper shape: math/code up vs the base model, IFEval slightly down — \
         RL trains only math+code)"
    );
    out.save("runs/table1_benchmarks.jsonl")?;
    println!("series written to runs/table1_benchmarks.jsonl");
    Ok(())
}
