//! Fig 7: asynchronous RL (k = 1, 2, 4) matches the synchronous (k = 0)
//! baseline's reward trajectory. Same seed, same budget, only the policy
//! lag differs.
//!
//!   cargo run --release --bin fig7_async_ablation -- --rl-steps 12

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{render_table, sparkline, Series};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let base = RunConfig {
        rl_steps: 10,
        pretrain_steps: 80,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 14,
        ..Default::default()
    }
    .apply_args(&args);

    println!("== Fig 7: sync vs async-k reward trajectories ==");
    let out = Series::default();
    let mut rows = Vec::new();
    let mut curves: Vec<(u64, Vec<f64>)> = Vec::new();
    for k in [0u64, 1, 2, 4] {
        let cfg = RunConfig { async_level: k, ..base.clone() };
        let pipeline = SyncPipeline::new(cfg.clone())?;
        let state = pipeline.bootstrap()?;
        pipeline.run_rl(state, cfg.rl_steps, "", false)?;
        let xs: Vec<f64> = pipeline.series.smoothed("task_reward", 3).iter().map(|x| x.1).collect();
        for (i, v) in xs.iter().enumerate() {
            out.push(i as u64, &format!("async{k}_task_reward"), *v);
        }
        rows.push(vec![
            format!("async-{k}{}", if k == 0 { " (sync baseline)" } else { "" }),
            format!("{:.3}", xs.first().unwrap_or(&0.0)),
            format!("{:.3}", xs.last().unwrap_or(&0.0)),
            sparkline(&xs),
        ]);
        curves.push((k, xs));
    }
    println!("{}", render_table(&["setting", "reward@0", "reward@end", "trajectory"], &rows));

    // Paper claim: trajectories match up to async-4. Report max deviation
    // of each async curve from the sync baseline over the common suffix.
    let sync = &curves[0].1;
    for (k, xs) in &curves[1..] {
        let dev = xs
            .iter()
            .zip(sync)
            .skip(xs.len() / 2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!("async-{k}: max late-half deviation from sync = {dev:.3}");
    }
    out.save("runs/fig7_async_ablation.jsonl")?;
    println!("series written to runs/fig7_async_ablation.jsonl");
    Ok(())
}
