//! Serving-SLO benchmark: drive mixed user-query + RL-rollout load
//! through the serve harness (front-door router, priority lane refill,
//! signed responses spot-checked by the sampling gate) three ways —
//! serve-free baseline, mixed load, and mixed load with a forging
//! server — and emit `BENCH_serving.json` for the regression gate.
//! Engine-free (MockBackend) and clock-simulated, so every figure is
//! deterministic and CI-stable.
//!
//!   cargo run --release --bin serving_bench
//!
//! Hard gates (exit non-zero, not statistics):
//! - every submitted query is served, and served within its SLO;
//! - the RL quota completes and stays byte-identical to the solo
//!   static-reference run under serve co-tenancy;
//! - mixed-load RL goodput stays >= 70% of the serve-free baseline;
//! - the forging server is slashed; honest servers never are, and the
//!   forged query is re-served by an honest worker.

use intellect2::coordinator::{run_serve_load, ServeLoadConfig};
use intellect2::util::bench::BenchReport;

fn main() -> anyhow::Result<()> {
    let mixed_cfg = ServeLoadConfig::default();
    let base_cfg = ServeLoadConfig { queries_per_step: 0, ..ServeLoadConfig::default() };
    let forger_cfg = ServeLoadConfig { forger: Some(2), ..ServeLoadConfig::default() };

    println!(
        "baseline: {} steps x {} workers x {} rollouts, no user traffic ...",
        base_cfg.steps, base_cfg.n_workers, base_cfg.rl_rollouts_per_worker
    );
    let base = run_serve_load(&base_cfg)?;
    anyhow::ensure!(base.rl_byte_equal, "baseline RL bytes diverged from static reference");
    println!(
        "baseline: {} rollouts, {} RL tokens over {} ticks ({:.4} tokens/tick)",
        base.rl_rollouts,
        base.rl_tokens,
        base.backend_ticks,
        base.rl_goodput()
    );

    println!(
        "mixed: + {} queries/step (max_new {}, SLO {}ms) ...",
        mixed_cfg.queries_per_step, mixed_cfg.max_new, mixed_cfg.slo_ms
    );
    let mixed = run_serve_load(&mixed_cfg)?;
    let ttft_p50 = mixed.ttft_percentile_ms(0.5);
    let ttft_p99 = mixed.ttft_percentile_ms(0.99);
    println!(
        "mixed: {}/{} queries served ({} tokens), TTFT p50 {}ms p99 {}ms, {} verified + {} \
         spot-check skipped",
        mixed.queries_served,
        mixed.queries_submitted,
        mixed.served_tokens,
        ttft_p50,
        ttft_p99,
        mixed.serve_verified,
        mixed.serve_skipped
    );
    anyhow::ensure!(
        mixed.queries_served == mixed.queries_submitted,
        "{} of {} queries never served",
        mixed.queries_submitted - mixed.queries_served,
        mixed.queries_submitted
    );
    anyhow::ensure!(
        mixed.deadlines_missed == 0,
        "{} served queries blew their SLO",
        mixed.deadlines_missed
    );
    anyhow::ensure!(mixed.rl_byte_equal, "serve co-tenancy changed RL rollout bytes");
    anyhow::ensure!(
        mixed.rl_rollouts == base.rl_rollouts && mixed.rl_tokens == base.rl_tokens,
        "RL quota changed under serve load: {} rollouts / {} tokens vs {} / {}",
        mixed.rl_rollouts,
        mixed.rl_tokens,
        base.rl_rollouts,
        base.rl_tokens
    );
    anyhow::ensure!(mixed.honest_slashed == 0, "honest server slashed under mixed load");

    // Goodput retention: RL tokens per backend call, mixed over baseline.
    let retention = mixed.rl_goodput() / base.rl_goodput();
    println!(
        "goodput: {:.4} vs {:.4} RL tokens/tick ({:.0}% retained)",
        mixed.rl_goodput(),
        base.rl_goodput(),
        retention * 100.0
    );
    anyhow::ensure!(
        retention >= 0.7,
        "RL goodput under serve load fell below 70% of serve-free ({:.0}%)",
        retention * 100.0
    );

    println!("forger: worker {} forges its served completions ...", 2);
    let forged = run_serve_load(&forger_cfg)?;
    println!(
        "forger: {} rejected, {} forger slashed / {} honest slashed, {}/{} queries still served",
        forged.serve_rejected,
        forged.forged_slashed,
        forged.honest_slashed,
        forged.queries_served,
        forged.queries_submitted
    );
    anyhow::ensure!(forged.forged_slashed == 1, "forging server escaped the slash");
    anyhow::ensure!(forged.honest_slashed == 0, "honest server slashed in the forger run");
    anyhow::ensure!(
        forged.queries_served == forged.queries_submitted,
        "forged query was dropped instead of re-served"
    );

    // Served tokens per simulated second of fleet time (ticks are the
    // simulated clock; tick_ms converts to wall-equivalent seconds).
    let sim_secs = (mixed.backend_ticks * mixed_cfg.tick_ms) as f64 / 1e3;
    let served_tokens_per_s = mixed.served_tokens as f64 / sim_secs.max(1e-9);

    let mut rep = BenchReport::new("serving");
    rep.metric("ttft_p50_ms", ttft_p50 as f64);
    rep.metric("ttft_p99_ms", ttft_p99 as f64);
    rep.metric("served_tokens_per_s", served_tokens_per_s);
    rep.metric("rl_goodput_retention", retention);
    rep.metric("queries_served", mixed.queries_served as f64);
    rep.metric(
        "serve_token_share",
        mixed.served_tokens as f64 / (mixed.served_tokens + mixed.rl_tokens).max(1) as f64,
    );
    let path = rep.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
