//! §4.2 "Compute Utilization": run the real decentralized swarm with
//! shaped bandwidth and report the paper's table — broadcast time,
//! time-to-batch, train time, overlap, and the inference:train FLOPs
//! ratio (paper: broadcast ≈ 14 min at ~590 Mb/s for 62 GB; batch ready
//! ≈ 22/29 min; FLOPs ratio ≈ 4.5x).
//!
//!   cargo run --release --bin util_table -- --rl-steps 4 --worker-ingress-bps 2000000

use intellect2::config::RunConfig;
use intellect2::coordinator::Swarm;
use intellect2::util::cli::Args;
use intellect2::util::metrics::render_table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig {
        rl_steps: 4,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 16,
        pretrain_steps: 40,
        n_workers: 3,
        n_relays: 2,
        // Shape worker downlinks and the origin uplink to make the
        // broadcast non-trivial, like the paper's WAN links.
        worker_ingress_bps: args.u64_or("worker-ingress-bps", 2_000_000),
        origin_egress_bps: args.u64_or("origin-egress-bps", 1_000_000),
        ..Default::default()
    }
    .apply_args(&args);

    println!("== §4.2 compute utilization (real swarm, shaped bandwidth) ==");
    let swarm = Swarm::new(cfg.clone())?;
    let spec = swarm.host.spec().clone();
    let result = swarm.run(cfg.pretrain_steps, false)?;

    // Per-step timing table with the overlap rendered as the fraction of
    // each broadcast hidden behind subsequent training (the §3.2 claim,
    // from real timestamps rather than the old wait-ratio proxy).
    let rows = result.timing_rows_with(|t, overlap| {
        overlap
            .map(|o| {
                if t.broadcast_secs > 1e-9 {
                    format!("{:.0}%", 100.0 * (o / t.broadcast_secs).min(1.0))
                } else {
                    "100%".into()
                }
            })
            .unwrap_or_else(|| "-".into())
    });
    println!(
        "{}",
        render_table(
            &["step", "broadcast_s", "batch_ready_s", "train_s", "bcast hidden"],
            &rows
        )
    );

    // Per-environment pass rates over verified rollouts: in a mixed-env
    // run the aggregate reward hides which scenarios actually learn.
    if !result.stats.env_pass.is_empty() {
        println!(
            "{}",
            render_table(
                &["environment", "verified rollouts", "pass rate"],
                &result.stats.env_pass.rows()
            )
        );
    }

    // Generation-engine scheduler counters (the gen side of the Fig-3
    // compute story, next to the validator columns above): decode steps,
    // bucketed prompt-prefill calls, unique prompt forwards (group-shared
    // prompts count once per wave, not once per rollout) and decode-lane
    // occupancy. Both engines fill the decode/occupancy rows; zero
    // prefill calls (with occupancy dipping on straggler tails) is the
    // signature of the static reference engine (`--gen-refill false` or
    // pre-refill artifacts).
    let s = &result.stats;
    if s.gen_lane_slots.get() > 0 {
        let steps = s.gen_decode_steps.get();
        let decoded = s.decode_tokens.get();
        let gen_rows = vec![
            vec!["decode steps".into(), steps.to_string()],
            vec!["prefill calls".into(), s.gen_prefill_calls.get().to_string()],
            vec!["unique prompt forwards".into(), s.gen_prefill_prompts.get().to_string()],
            vec![
                "lane occupancy".into(),
                format!(
                    "{:.1}%",
                    100.0 * s.gen_lane_active.get() as f64 / s.gen_lane_slots.get().max(1) as f64
                ),
            ],
            vec![
                "tokens per decode step".into(),
                format!("{:.2}", decoded as f64 / steps.max(1) as f64),
            ],
        ];
        println!("{}", render_table(&["generation engine", "value"], &gen_rows));
    }

    // Trust-weighted sampled validation: how the gate split the upload
    // stream (full verification vs spot-check-exempt vs re-escalated) and
    // how many rollouts were admitted on stake + trust alone. Zero rows
    // mean the gate never armed (`--sampling-rate 1.0`, the default).
    let gated = s.submissions_sampled_full.get()
        + s.submissions_skipped_unverified.get()
        + s.submissions_rejected_unsampled.get();
    if gated > 0 {
        let share = |n: u64| format!("{n} ({:.0}%)", 100.0 * n as f64 / gated as f64);
        let gate_rows = vec![
            vec!["fully verified".into(), share(s.submissions_sampled_full.get())],
            vec!["skipped (stake-backed)".into(), share(s.submissions_skipped_unverified.get())],
            vec![
                "rejected unsampled (deterministic)".into(),
                s.submissions_rejected_unsampled.get().to_string(),
            ],
            vec!["re-escalated".into(), s.submissions_escalated.get().to_string()],
            vec![
                "rollouts admitted unverified".into(),
                s.rollouts_admitted_unverified.get().to_string(),
            ],
        ];
        println!("{}", render_table(&["sampled validation", "submissions"], &gate_rows));
    }

    // Serve mode: the same fleet answering user traffic. The swarm run
    // above is rollout-only, so the serving columns come from the
    // engine-free mixed-load harness (same scheduler, same trust stack,
    // simulated SLO clock — deterministic figures).
    let serve_cfg = intellect2::coordinator::ServeLoadConfig::default();
    let serve = intellect2::coordinator::run_serve_load(&serve_cfg)?;
    let serve_ticks_ms = (serve.backend_ticks * serve_cfg.tick_ms).max(1) as f64;
    let serve_rows = vec![
        vec![
            "queries served".into(),
            format!("{} of {}", serve.queries_served, serve.queries_submitted),
        ],
        vec!["TTFT p50".into(), format!("{} ms", serve.ttft_percentile_ms(0.5))],
        vec!["TTFT p99".into(), format!("{} ms", serve.ttft_percentile_ms(0.99))],
        vec![
            "served tokens/s".into(),
            format!("{:.0}", serve.served_tokens as f64 / (serve_ticks_ms / 1e3)),
        ],
        vec![
            "serve share of lane slots".into(),
            format!(
                "{:.1}%",
                100.0 * serve.served_tokens as f64
                    / (serve.served_tokens + serve.rl_tokens).max(1) as f64
            ),
        ],
        vec![
            "spot-checks".into(),
            format!("{} full + {} skipped", serve.serve_verified, serve.serve_skipped),
        ],
    ];
    println!("{}", render_table(&["serving (mixed-load harness)", "value"], &serve_rows));

    // Off-policy staleness accounting (the two-step-async correctness knob).
    let hist = result.stats.staleness_hist();
    let trained: u64 = hist.iter().map(|(_, n)| n).sum();
    let hist_rows: Vec<Vec<String>> = hist
        .iter()
        .map(|(lag, n)| {
            vec![
                format!("lag {lag}"),
                n.to_string(),
                format!("{:.1}%", 100.0 * *n as f64 / trained.max(1) as f64),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(&["policy staleness", "rollouts", "share"], &hist_rows)
    );
    println!(
        "stale-dropped rollouts: {} | stale submissions: {} (window k={})\n",
        result.stats.rollouts_dropped_stale.get(),
        result.stats.submissions_stale.get(),
        cfg.async_level
    );

    // FLOPs accounting: train ≈ 6 * P * tokens_trained (fwd+bwd), inference
    // ≈ 2 * P * tokens_decoded per token (KV-cache decode).
    let p = spec.n_params as f64;
    let decode_tokens = result.stats.decode_tokens.get() as f64;
    let trained_tokens = (cfg.rl_steps * cfg.micro_steps as u64) as f64
        * (spec.batch_train * spec.max_seq) as f64;
    let inf_flops = 2.0 * p * decode_tokens;
    let train_flops = 6.0 * p * trained_tokens;
    let total_bytes = result.stats.broadcast_bytes.get();
    let mean_bcast = result.step_timings.iter().map(|t| t.broadcast_secs).sum::<f64>()
        / result.step_timings.len().max(1) as f64;
    println!(
        "\ncheckpoint size: {:.2} MB | mean broadcast: {mean_bcast:.2}s | effective {:.1} Mb/s",
        spec.params_bytes() as f64 / 1e6,
        spec.params_bytes() as f64 * 8.0 / 1e6 / mean_bcast.max(1e-9)
    );
    println!(
        "decoded tokens: {decode_tokens:.0} | trained tokens: {trained_tokens:.0} | \
         inference:train FLOPs ratio = {:.2}x (paper: ~4.5x; grows with rollout length)",
        inf_flops / train_flops.max(1.0)
    );
    println!("total bytes broadcast: {:.1} MB", total_bytes as f64 / 1e6);
    result.series.save("runs/util_table.jsonl")?;
    println!("series written to runs/util_table.jsonl");
    Ok(())
}
