//! Cheat-EV gate: proves the trust-weighted sampling gate is safe to ship
//! at any configured rate, or fails CI trying. Engine-free (the catch is
//! stage 2's CPU reward re-verification), so it runs on a bare checkout.
//!
//!   cargo run --release --bin cheat_ev_bench
//!
//! Hard gates (exit non-zero, not statistics), per sampling rate in
//! {1.0, 0.25, 0.1}:
//! - the analytic per-cheat expected value is negative with the stake the
//!   run actually bonded: `(1 - p) * reward < p * stake`;
//! - every node that submitted a fabricated-reward submission ends the
//!   run slashed with its full stake forfeited;
//! - deterministic lies — inflated rollout counts past the quota,
//!   out-of-bounds claimed rewards — never bank a unit, sampled or not
//!   (the gate's cheap CPU checks run on the skip path too);
//! - no honest node is slashed;
//! - at rate 1.0 the gated pipeline's verdict stream is byte-identical to
//!   the ungated (pre-sampling) pipeline over the same upload bytes.
//!
//! Emits `BENCH_cheatev.json` with the per-rate EV margins and the
//! realized spot-check skip share, for the perf/safety trajectory.

use intellect2::coordinator::{run_cheat_ev, CheatEvConfig, CheatEvReport, Strategy};
use intellect2::util::bench::BenchReport;

fn gate(rate: f64) -> anyhow::Result<CheatEvReport> {
    let cfg = CheatEvConfig { sampling_rate: rate, ..Default::default() };
    let r = run_cheat_ev(&cfg)?;
    println!(
        "rate {rate:.2}: {} uploads — {} fully verified, {} skipped, {} escalated, \
         {} settled deterministically unsampled; stake {} units vs {} units/submission",
        r.uploads,
        r.sampled_full,
        r.skipped,
        r.escalated,
        r.rejected_unsampled,
        r.stake,
        r.per_sub_reward
    );
    for n in r.nodes.iter().filter(|n| n.is_cheater()) {
        println!(
            "  {:?}: {} cheats ({} admitted, {} units banked), slashed={}, forfeited {}",
            n.strategy, n.cheats_submitted, n.cheats_admitted, n.cheat_gain, n.slashed,
            n.forfeited
        );
    }
    anyhow::ensure!(
        r.analytic_cheat_ev() < 0.0,
        "rate {rate}: cheating is positive-EV ({:+.2} units/cheat) — stake sizing broken",
        r.analytic_cheat_ev()
    );
    anyhow::ensure!(
        r.cheaters_escaped() == 0,
        "rate {rate}: {} cheater(s) finished the run unslashed",
        r.cheaters_escaped()
    );
    anyhow::ensure!(
        r.honest_slashed() == 0,
        "rate {rate}: {} honest node(s) slashed",
        r.honest_slashed()
    );
    for n in r.nodes.iter().filter(|n| n.cheats_submitted > 0) {
        anyhow::ensure!(
            n.forfeited == r.stake,
            "rate {rate}: {:?} slashed but only {} of {} stake units forfeited",
            n.strategy,
            n.forfeited,
            r.stake
        );
    }
    // Deterministic lies (count inflation, out-of-bounds claims) must
    // never bank a single unit — the gate's cheap CPU checks run on the
    // skip path too, so losing the selection draw buys nothing.
    for n in r
        .nodes
        .iter()
        .filter(|n| matches!(n.strategy, Strategy::Inflator | Strategy::BoundsLiar))
    {
        anyhow::ensure!(
            n.cheats_admitted == 0 && n.cheat_gain == 0,
            "rate {rate}: {:?} got a deterministic lie admitted ({} subs, {} units)",
            n.strategy,
            n.cheats_admitted,
            n.cheat_gain
        );
    }
    Ok(r)
}

fn main() -> anyhow::Result<()> {
    let mut rep = BenchReport::new("cheatev");

    let full = gate(1.0)?;
    anyhow::ensure!(
        full.skipped == 0,
        "rate 1.0 must disable spot-check exemption ({} skips)",
        full.skipped
    );
    anyhow::ensure!(
        full.gated_fingerprints == full.baseline_fingerprints,
        "rate 1.0 verdicts diverge from the pre-sampling pipeline: {} gated vs {} baseline",
        full.gated_fingerprints.len(),
        full.baseline_fingerprints.len()
    );
    println!(
        "rate 1.00: verdict stream identical to ungated pipeline ({} verdicts)",
        full.gated_fingerprints.len()
    );

    let quarter = gate(0.25)?;
    let tenth = gate(0.1)?;

    // EV margin = how many reward units below break-even a cheat sits
    // (positive = safe; the gates above already enforce > 0).
    rep.metric("cheat_ev_margin_rate100", -full.analytic_cheat_ev());
    rep.metric("cheat_ev_margin_rate25", -quarter.analytic_cheat_ev());
    rep.metric("cheat_ev_margin_rate10", -tenth.analytic_cheat_ev());
    // Realized adversarial outcomes (worst cheater's profit, negated so
    // higher = safer; zero cheaters escaped is gated above).
    rep.metric("cheater_worst_loss_rate10", -(tenth.worst_realized_profit() as f64));
    // Throughput side of the story: share of uploads the gate exempted
    // from stages 1-5 (higher = more validator compute saved).
    rep.metric("spotcheck_skip_share_rate25", quarter.skipped as f64 / quarter.uploads as f64);
    rep.metric("spotcheck_skip_share_rate10", tenth.skipped as f64 / tenth.uploads as f64);
    let path = rep.write()?;
    println!("wrote {}", path.display());
    println!("cheat-EV gate: all rates safe (negative EV, cheaters slashed, honest intact)");
    Ok(())
}
