//! Fig 10: the entropy-loss pattern — policy entropy first decreases, then
//! resurges; the resurgence precedes reward collapse. Reproduced in the
//! unmitigated high-lr regime.
//!
//!   cargo run --release --bin fig10_entropy -- --rl-steps 20

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{sparkline, Series};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig {
        rl_steps: 18,
        pretrain_steps: 80,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 3,
        max_new_tokens: 12,
        ..Default::default()
    }
    .apply_args(&args);
    // Unmitigated regime to surface the pattern within a short run.
    cfg.hp.lr *= 40.0;
    cfg.hp.grad_clip = 1e9;
    cfg.hp.delta = 1e9;
    cfg.hp.ent_coef = 0.0;
    cfg.hp.kl_coef = 0.0;

    println!("== Fig 10: entropy dip -> resurgence -> collapse ==");
    let pipeline = SyncPipeline::new(cfg.clone())?;
    let state = pipeline.bootstrap()?;
    pipeline.run_rl(state, cfg.rl_steps, "", false)?;

    let ent: Vec<f64> = pipeline.series.get("entropy").iter().map(|x| x.1).collect();
    let reward: Vec<f64> = pipeline.series.get("task_reward").iter().map(|x| x.1).collect();
    println!("entropy     {}  {:?}", sparkline(&ent), summarize(&ent));
    println!("task reward {}  {:?}", sparkline(&reward), summarize(&reward));

    // Detect the pattern: argmin of entropy strictly inside the run, with
    // later entropy above the minimum (resurgence).
    let (imin, emin) = ent
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, v)| (i, *v))
        .unwrap_or((0, 0.0));
    let tail_max = ent[imin..].iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "\nentropy minimum at step {imin} ({emin:.3}); post-minimum max {tail_max:.3} \
         -> resurgence {}",
        if tail_max > emin * 1.05 { "OBSERVED" } else { "not observed at this scale/budget" }
    );

    let out = Series::default();
    for (i, (e, r)) in ent.iter().zip(&reward).enumerate() {
        out.push(i as u64, "entropy", *e);
        out.push(i as u64, "task_reward", *r);
    }
    out.save("runs/fig10_entropy.jsonl")?;
    println!("series written to runs/fig10_entropy.jsonl");
    Ok(())
}

fn summarize(xs: &[f64]) -> (f64, f64) {
    (
        *xs.first().unwrap_or(&0.0),
        *xs.last().unwrap_or(&0.0),
    )
}
