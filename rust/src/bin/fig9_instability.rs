//! Fig 9a/9b: gradient norms and token-probability clip ratios escalate
//! with model scale. We sweep model sizes under a deliberately unstable
//! regime (high lr, clipping disabled à la the paper's unmitigated runs)
//! and report the growth of both curves.
//!
//!   cargo run --release --bin fig9_instability -- --rl-steps 12 --sizes nano,micro

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{render_table, sparkline, Series};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let sizes = args.str_or("sizes", "nano,micro");
    let base = RunConfig {
        rl_steps: 12,
        pretrain_steps: 60,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 12,
        ..Default::default()
    }
    .apply_args(&args);

    println!("== Fig 9: instability escalation across model scale ==");
    println!("(unmitigated regime: lr x30, grad clip off, delta cap off)\n");
    let out = Series::default();
    let mut rows = Vec::new();
    for size in sizes.split(',') {
        let mut cfg = RunConfig { model: size.into(), ..base.clone() };
        // The unmitigated recipe (what the paper observed before §3.4/§3.5):
        cfg.hp.lr *= 30.0;
        cfg.hp.grad_clip = 1e9; // no aggressive clipping
        cfg.hp.delta = 1e9; // effectively one-sided clipping
        let pipeline = match SyncPipeline::new(cfg.clone()) {
            Ok(p) => p,
            Err(e) => {
                println!("[skip {size}: {e}]");
                continue;
            }
        };
        let state = pipeline.bootstrap()?;
        pipeline.run_rl(state, cfg.rl_steps, "", false)?;
        let gnorm: Vec<f64> = pipeline.series.get("gnorm").iter().map(|x| x.1).collect();
        let clip: Vec<f64> = pipeline.series.get("clipfrac").iter().map(|x| x.1).collect();
        for (i, (g, c)) in gnorm.iter().zip(&clip).enumerate() {
            out.push(i as u64, &format!("{size}_gnorm"), *g);
            out.push(i as u64, &format!("{size}_clipfrac"), *c);
        }
        let half = gnorm.len() / 2;
        let early = gnorm[..half].iter().sum::<f64>() / half.max(1) as f64;
        let late = gnorm[half..].iter().sum::<f64>() / (gnorm.len() - half).max(1) as f64;
        rows.push(vec![
            size.to_string(),
            format!("{early:.3}"),
            format!("{late:.3}"),
            format!("{:.2}x", late / early.max(1e-9)),
            sparkline(&gnorm),
            sparkline(&clip),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["model", "gnorm early", "gnorm late", "growth", "gnorm traj", "clipfrac traj"],
            &rows
        )
    );
    println!("(paper: larger models show earlier/steeper gnorm + clip-ratio escalation)");
    out.save("runs/fig9_instability.jsonl")?;
    println!("series written to runs/fig9_instability.jsonl");
    Ok(())
}
