//! Fig 12: TARGET-SHORT vs TARGET-LONG — task rewards climb in both runs;
//! length penalties trend down slowly (much slower than the small-model
//! ablations, per the paper). Curves smoothed by a 10-step-style moving
//! average (we use 3 at this budget).
//!
//!   cargo run --release --bin fig12_target_runs -- --rl-steps 14

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::rl::reward::RewardConfig;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{render_table, sparkline, Series};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let base = RunConfig {
        rl_steps: 12,
        pretrain_steps: 100,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 80,
        ..Default::default()
    }
    .apply_args(&args);

    println!("== Fig 12: TARGET-SHORT vs TARGET-LONG (task reward / length penalty) ==");
    let out = Series::default();
    let mut rows = Vec::new();
    for (label, reward) in [
        ("TARGET-SHORT", RewardConfig::target_short()),
        ("TARGET-LONG", RewardConfig::target_long()),
    ] {
        let cfg = RunConfig { reward: reward.clone(), ..base.clone() };
        let pipeline = SyncPipeline::new(cfg.clone())?;
        let state = pipeline.bootstrap()?;
        pipeline.run_rl(state, cfg.rl_steps, "", false)?;
        let task: Vec<f64> = pipeline.series.smoothed("task_reward", 3).iter().map(|x| x.1).collect();
        let pen: Vec<f64> = pipeline.series.smoothed("length_penalty", 3).iter().map(|x| x.1).collect();
        let lens: Vec<f64> = pipeline.series.get("completion_len").iter().map(|x| x.1).collect();
        for (i, ((t, p), l)) in task.iter().zip(&pen).zip(&lens).enumerate() {
            out.push(i as u64, &format!("{label}_task_reward"), *t);
            out.push(i as u64, &format!("{label}_length_penalty"), *p);
            out.push(i as u64, &format!("{label}_completion_len"), *l);
        }
        rows.push(vec![
            label.to_string(),
            format!("{:?}", reward.targets),
            format!("{:.3} -> {:.3}  {}", task.first().unwrap_or(&0.0), task.last().unwrap_or(&0.0), sparkline(&task)),
            format!("{:.3} -> {:.3}  {}", pen.first().unwrap_or(&0.0), pen.last().unwrap_or(&0.0), sparkline(&pen)),
        ]);
    }
    println!("{}", render_table(&["run", "targets", "task reward", "length penalty"], &rows));
    println!("(paper: rewards rise in both; penalties fall but do not converge in-budget)");
    out.save("runs/fig12_target_runs.jsonl")?;
    println!("series written to runs/fig12_target_runs.jsonl");
    Ok(())
}
