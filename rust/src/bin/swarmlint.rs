//! swarmlint — the determinism / slashability lint gate.
//!
//! Scans the crate sources (`src/`, or `rust/src/` when run from the repo
//! root) with the rules in [`intellect2::analysis`] and exits nonzero on
//! any unsuppressed violation. Prints the whole-crate lock map and the
//! suppression summary table either way, so `make lint` doubles as the
//! audit report reviewers read.
//!
//!   swarmlint [--root <dir>] [--quiet]

use std::path::Path;
use std::process::ExitCode;

use intellect2::analysis::{analyze_tree, lockmap, rules};
use intellect2::util::cli::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    let quiet = args.has_flag("quiet");
    let root = if let Some(r) = args.get("root") {
        r.to_string()
    } else if Path::new("src").is_dir() {
        "src".to_string()
    } else if Path::new("rust/src").is_dir() {
        "rust/src".to_string()
    } else {
        eprintln!("swarmlint: no src/ or rust/src/ here; pass --root <dir>");
        return ExitCode::FAILURE;
    };
    let cfg = rules::repo_config();
    let reports = match analyze_tree(Path::new(&root), &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("swarmlint: failed to read {root}: {e}");
            return ExitCode::FAILURE;
        }
    };

    let mut n_unsuppressed = 0usize;
    let mut n_suppressed = 0usize;
    for r in &reports {
        for v in &r.violations {
            if v.suppressed {
                n_suppressed += 1;
            } else {
                n_unsuppressed += 1;
                println!("{}:{} [{}] {}", v.file, v.line, v.rule.name(), v.message);
            }
        }
    }

    if !quiet {
        println!();
        print!("{}", lockmap::render_map(&reports, &cfg.lock_order));
        println!();
        println!("suppressions ({n_suppressed} violations under annotation):");
        let mut any = false;
        for r in &reports {
            for a in &r.annotations {
                if !a.used {
                    continue;
                }
                any = true;
                let scope = if a.fn_scoped { "fn" } else { "line" };
                let names: Vec<&str> = a.rules.iter().map(|x| x.name()).collect();
                println!(
                    "  {}:{} [{}] ({}) {}",
                    r.file,
                    a.line,
                    names.join(","),
                    scope,
                    a.justification
                );
            }
        }
        if !any {
            println!("  none");
        }
        for r in &reports {
            for a in &r.annotations {
                if !a.used {
                    println!(
                        "::warning::{}:{} unused swarmlint annotation ({})",
                        r.file,
                        a.line,
                        a.justification
                    );
                }
            }
        }
        println!();
    }

    let files = reports.len();
    if n_unsuppressed == 0 {
        println!("swarmlint: clean ({files} files, {n_suppressed} suppressed)");
        ExitCode::SUCCESS
    } else {
        println!("swarmlint: {n_unsuppressed} unsuppressed violation(s) in {files} files");
        ExitCode::FAILURE
    }
}
