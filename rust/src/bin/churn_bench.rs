//! Churn goodput benchmark: run the torture harness twice — once
//! fault-free, once with process churn (worker crash + relay kill + fresh
//! join every step) and request-level fault injection on every relay —
//! and compare throughput. Engine-free (synthetic checkpoints), so it
//! runs in CI without model artifacts. Emits `BENCH_churn.json` for the
//! regression gate.
//!
//!   cargo run --release --bin churn_bench
//!
//! Hard gates (exit non-zero, not statistics):
//! - both runs complete every step within the per-step deadline;
//! - no honest node is slashed under churn;
//! - goodput under churn stays >= 50% of the fault-free baseline;
//! - the churn run samples its payload audits (rate 0.25): some fetches
//!   are fully audited, some admitted unaudited, and every audit passes
//!   (an audit mismatch fails the fetch task, stalling the step quota).

use intellect2::coordinator::{run_churn, ChurnConfig};
use intellect2::http::FaultSpec;
use intellect2::util::bench::BenchReport;

fn main() -> anyhow::Result<()> {
    // Baseline audits every fetch (rate 1.0); the churn run exercises the
    // commitment-sampled audit path on top of process + request faults.
    let base_cfg = ChurnConfig::default();
    let churn_cfg = ChurnConfig {
        churn: true,
        server_faults: Some(FaultSpec {
            fault_rate: 0.25,
            burst_len: 2,
            hang_ms: 150,
            ..FaultSpec::default()
        }),
        sampling_rate: 0.25,
        ..ChurnConfig::default()
    };

    println!("baseline: {} steps, fault-free ...", base_cfg.steps);
    let base = run_churn(&base_cfg)?;
    anyhow::ensure!(
        base.steps_completed == base_cfg.steps,
        "baseline incomplete: {} of {} steps",
        base.steps_completed,
        base_cfg.steps
    );
    println!(
        "baseline: {} tasks in {:.2}s ({} retries)",
        base.tasks_completed, base.elapsed_secs, base.fetch_retries
    );

    println!("churn: {} steps, crash+kill+join per step, faulty relays ...", churn_cfg.steps);
    let churn = run_churn(&churn_cfg)?;
    println!(
        "churn: {} tasks in {:.2}s ({} retries, {} crashed, {} joined, {} relays killed, \
         {} evicted, {} requeued, {} reparents)",
        churn.tasks_completed,
        churn.elapsed_secs,
        churn.fetch_retries,
        churn.workers_crashed,
        churn.workers_joined,
        churn.relays_killed,
        churn.workers_evicted,
        churn.tasks_requeued,
        churn.reparent_events
    );
    anyhow::ensure!(
        churn.steps_completed == churn_cfg.steps,
        "churn run incomplete: {} of {} steps",
        churn.steps_completed,
        churn_cfg.steps
    );
    anyhow::ensure!(
        churn.honest_slashed == 0,
        "{} honest node(s) slashed under churn",
        churn.honest_slashed
    );
    println!(
        "audits: baseline {}/{} full, churn {} full + {} skipped at rate {}",
        base.audits_full,
        base.audits_full + base.audits_skipped,
        churn.audits_full,
        churn.audits_skipped,
        churn_cfg.sampling_rate
    );
    anyhow::ensure!(
        base.audits_skipped == 0,
        "baseline at rate 1.0 skipped {} audits",
        base.audits_skipped
    );
    anyhow::ensure!(
        churn.audits_full > 0 && churn.audits_skipped > 0,
        "sampled auditing degenerate: {} full / {} skipped",
        churn.audits_full,
        churn.audits_skipped
    );

    // Goodput: completed steps per wall-clock second, churn over baseline.
    let base_rate = base.steps_completed as f64 / base.elapsed_secs;
    let churn_rate = churn.steps_completed as f64 / churn.elapsed_secs;
    let goodput_ratio = churn_rate / base_rate;
    // Mean extra wall clock per step that recovery (eviction, requeue,
    // failover, re-parenting) costs under churn.
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let recovery_overhead = mean(&churn.step_secs) - mean(&base.step_secs);
    println!(
        "goodput: {churn_rate:.2} vs {base_rate:.2} steps/s ({:.0}% of fault-free), \
         +{recovery_overhead:.3}s/step recovery",
        goodput_ratio * 100.0
    );
    anyhow::ensure!(
        goodput_ratio >= 0.5,
        "goodput under churn fell below 50% of fault-free ({:.0}%)",
        goodput_ratio * 100.0
    );

    let mut rep = BenchReport::new("churn");
    rep.metric("goodput_ratio", goodput_ratio);
    rep.metric("steps_completed", churn.steps_completed as f64);
    rep.metric("recovery_overhead", recovery_overhead.max(0.0));
    rep.metric("fetch_retry_calls", churn.fetch_retries as f64);
    rep.metric(
        "audit_coverage",
        churn.audits_full as f64 / (churn.audits_full + churn.audits_skipped).max(1) as f64,
    );
    let path = rep.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
