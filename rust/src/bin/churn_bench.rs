//! Churn goodput benchmark: run the torture harness twice — once
//! fault-free, once with process churn (worker crash + relay kill + fresh
//! join every step) and request-level fault injection on every relay —
//! and compare throughput. Engine-free (synthetic checkpoints), so it
//! runs in CI without model artifacts. Emits `BENCH_churn.json` for the
//! regression gate.
//!
//!   cargo run --release --bin churn_bench
//!
//! Hard gates (exit non-zero, not statistics):
//! - both runs complete every step within the per-step deadline;
//! - no honest node is slashed under churn;
//! - goodput under churn stays >= 50% of the fault-free baseline;
//! - the churn run samples its payload audits (rate 0.25): some fetches
//!   are fully audited, some admitted unaudited, and every audit passes
//!   (an audit mismatch fails the fetch task, stalling the step quota).
//!
//! The tree leg (`BENCH_shardcast.json`) runs the gossip-formed SHARDCAST
//! tree twice under an identical mid-epoch fault schedule (hub relay
//! killed + a survivor partitioned from its new parent) — once with full
//! raw broadcast, once with delta + q8 encoding — and gates on:
//! - delivery_rate == 1.0 on both legs (every live worker assembles a
//!   checksum-valid checkpoint for every step);
//! - membership converged by gossip alone: zero hits on the central
//!   discovery list endpoint, final views == the true live set;
//! - no honest node slashed;
//! - delta + q8 cuts measured origin egress >= 40% vs full broadcast.

use intellect2::coordinator::{run_churn, run_tree_churn, ChurnConfig, TreeChurnConfig};
use intellect2::http::FaultSpec;
use intellect2::util::bench::BenchReport;

fn main() -> anyhow::Result<()> {
    // Baseline audits every fetch (rate 1.0); the churn run exercises the
    // commitment-sampled audit path on top of process + request faults.
    let base_cfg = ChurnConfig::default();
    let churn_cfg = ChurnConfig {
        churn: true,
        server_faults: Some(FaultSpec {
            fault_rate: 0.25,
            burst_len: 2,
            hang_ms: 150,
            ..FaultSpec::default()
        }),
        sampling_rate: 0.25,
        ..ChurnConfig::default()
    };

    println!("baseline: {} steps, fault-free ...", base_cfg.steps);
    let base = run_churn(&base_cfg)?;
    anyhow::ensure!(
        base.steps_completed == base_cfg.steps,
        "baseline incomplete: {} of {} steps",
        base.steps_completed,
        base_cfg.steps
    );
    println!(
        "baseline: {} tasks in {:.2}s ({} retries)",
        base.tasks_completed, base.elapsed_secs, base.fetch_retries
    );

    println!("churn: {} steps, crash+kill+join per step, faulty relays ...", churn_cfg.steps);
    let churn = run_churn(&churn_cfg)?;
    println!(
        "churn: {} tasks in {:.2}s ({} retries, {} crashed, {} joined, {} relays killed, \
         {} evicted, {} requeued, {} reparents)",
        churn.tasks_completed,
        churn.elapsed_secs,
        churn.fetch_retries,
        churn.workers_crashed,
        churn.workers_joined,
        churn.relays_killed,
        churn.workers_evicted,
        churn.tasks_requeued,
        churn.reparent_events
    );
    anyhow::ensure!(
        churn.steps_completed == churn_cfg.steps,
        "churn run incomplete: {} of {} steps",
        churn.steps_completed,
        churn_cfg.steps
    );
    anyhow::ensure!(
        churn.honest_slashed == 0,
        "{} honest node(s) slashed under churn",
        churn.honest_slashed
    );
    println!(
        "audits: baseline {}/{} full, churn {} full + {} skipped at rate {}",
        base.audits_full,
        base.audits_full + base.audits_skipped,
        churn.audits_full,
        churn.audits_skipped,
        churn_cfg.sampling_rate
    );
    anyhow::ensure!(
        base.audits_skipped == 0,
        "baseline at rate 1.0 skipped {} audits",
        base.audits_skipped
    );
    anyhow::ensure!(
        churn.audits_full > 0 && churn.audits_skipped > 0,
        "sampled auditing degenerate: {} full / {} skipped",
        churn.audits_full,
        churn.audits_skipped
    );

    // Goodput: completed steps per wall-clock second, churn over baseline.
    let base_rate = base.steps_completed as f64 / base.elapsed_secs;
    let churn_rate = churn.steps_completed as f64 / churn.elapsed_secs;
    let goodput_ratio = churn_rate / base_rate;
    // Mean extra wall clock per step that recovery (eviction, requeue,
    // failover, re-parenting) costs under churn.
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let recovery_overhead = mean(&churn.step_secs) - mean(&base.step_secs);
    println!(
        "goodput: {churn_rate:.2} vs {base_rate:.2} steps/s ({:.0}% of fault-free), \
         +{recovery_overhead:.3}s/step recovery",
        goodput_ratio * 100.0
    );
    anyhow::ensure!(
        goodput_ratio >= 0.5,
        "goodput under churn fell below 50% of fault-free ({:.0}%)",
        goodput_ratio * 100.0
    );

    let mut rep = BenchReport::new("churn");
    rep.metric("goodput_ratio", goodput_ratio);
    rep.metric("steps_completed", churn.steps_completed as f64);
    rep.metric("recovery_overhead", recovery_overhead.max(0.0));
    rep.metric("fetch_retry_calls", churn.fetch_retries as f64);
    rep.metric(
        "audit_coverage",
        churn.audits_full as f64 / (churn.audits_full + churn.audits_skipped).max(1) as f64,
    );
    let path = rep.write()?;
    println!("wrote {}", path.display());

    // --- Tree leg: gossip-formed SHARDCAST tree under relay kill + partition.
    // Both legs share seed and fault schedule; only the wire encoding differs,
    // so the egress delta isolates what delta + q8 actually saves.
    let full_cfg = TreeChurnConfig { delta: false, quantize: false, ..TreeChurnConfig::default() };
    let enc_cfg = TreeChurnConfig::default();

    println!(
        "tree/full: {} steps, {} relays, kill+partition at step {} ...",
        full_cfg.steps, full_cfg.n_relays, full_cfg.fault_step
    );
    let full = run_tree_churn(&full_cfg)?;
    println!(
        "tree/full: {}/{} deliveries, {} origin bytes, reform in {} step(s)",
        full.deliveries, full.delivery_attempts, full.origin_egress_bytes, full.reform_latency_steps
    );
    println!("tree/delta+q8: {} steps, same fault schedule ...", enc_cfg.steps);
    let enc = run_tree_churn(&enc_cfg)?;
    println!(
        "tree/delta+q8: {}/{} deliveries ({} delta shards), {} origin bytes, \
         reform in {} step(s)",
        enc.deliveries,
        enc.delivery_attempts,
        enc.delta_shards,
        enc.origin_egress_bytes,
        enc.reform_latency_steps
    );

    let legs = [("full", &full, full_cfg.steps), ("delta+q8", &enc, enc_cfg.steps)];
    for (name, leg, steps) in legs {
        anyhow::ensure!(
            leg.steps_completed == steps,
            "tree/{name} incomplete: {} of {} steps",
            leg.steps_completed,
            steps
        );
        anyhow::ensure!(
            leg.delivery_rate == 1.0,
            "tree/{name} dropped checkpoints: delivery rate {:.3}",
            leg.delivery_rate
        );
        anyhow::ensure!(
            leg.relays_killed == 1 && leg.partitions_cut == 1,
            "tree/{name} fault schedule not exercised: {} killed, {} cut",
            leg.relays_killed,
            leg.partitions_cut
        );
        anyhow::ensure!(
            leg.partition_refusals > 0,
            "tree/{name} partition never refused a connection"
        );
        anyhow::ensure!(
            leg.reparent_events >= 1,
            "tree/{name} never re-parented around the fault"
        );
        anyhow::ensure!(
            leg.honest_slashed == 0,
            "tree/{name}: {} honest node(s) slashed",
            leg.honest_slashed
        );
        anyhow::ensure!(
            leg.gossip_converged,
            "tree/{name} gossip views did not converge to the live set"
        );
        anyhow::ensure!(
            leg.list_calls == 0,
            "tree/{name} fell back to the central list endpoint {} time(s)",
            leg.list_calls
        );
        anyhow::ensure!(leg.invites_via_gossip > 0, "tree/{name} invited no workers via gossip");
    }
    anyhow::ensure!(enc.delta_shards > 0, "encoded leg never served a delta shard");

    let savings = 1.0 - enc.origin_egress_bytes as f64 / full.origin_egress_bytes.max(1) as f64;
    println!(
        "origin egress: {} -> {} bytes ({:.0}% saved)",
        full.origin_egress_bytes,
        enc.origin_egress_bytes,
        savings * 100.0
    );
    anyhow::ensure!(
        savings >= 0.40,
        "delta + q8 saved only {:.0}% origin egress (need >= 40%)",
        savings * 100.0
    );

    let mut tree_rep = BenchReport::new("shardcast");
    tree_rep.metric("origin_egress_bytes", enc.origin_egress_bytes as f64);
    tree_rep.metric("delta_egress_savings", savings);
    tree_rep.metric("reform_latency_steps", enc.reform_latency_steps as f64);
    tree_rep.metric("delivery_rate", enc.delivery_rate);
    let tree_path = tree_rep.write()?;
    println!("wrote {}", tree_path.display());
    Ok(())
}
