//! Continuous-batching vs static-batch rollout generation on a
//! straggler-heavy workload, measured over the deterministic
//! [`MockBackend`] so the bench runs engine-free (and therefore in CI,
//! where no model artifacts are shipped). The scheduler is where the win
//! lives: the mock charges a dense per-call cost like a real device batch,
//! so decode-step counts translate directly to wall clock. Emits
//! `BENCH_generation.json` (rollouts/s, decode_steps, prefill calls /
//! unique prompt forwards, lane occupancy) for the CI regression gate.
//!
//!   cargo run --release --bin generation_bench
//!
//! The two paths are also byte-compared here — a mismatch is a hard
//! error, not a statistic.

use intellect2::runtime::scheduler::{
    rollout_rng, run_continuous, run_static_reference, GenRequest, GenStats, MockBackend,
    SchedSpec,
};
use intellect2::runtime::GenOpts;
use intellect2::util::bench::{BenchReport, Bencher};
use intellect2::util::rng::Rng;

/// A GRPO-shaped workload: `n_tasks` prompts of mixed lengths, each
/// repeated `group_size` times; the mock's per-sequence EOS rates make
/// completion lengths wildly uneven (early finishers + stragglers).
fn workload(sp: &SchedSpec, n_tasks: usize, group_size: usize, seed: u64) -> Vec<GenRequest> {
    let mut r = Rng::new(seed);
    let mut reqs = Vec::with_capacity(n_tasks * group_size);
    for task in 0..n_tasks {
        let len = 2 + r.usize(56); // 2..58 tokens: spans several buckets
        let mut prompt = vec![sp.bos_id];
        prompt.extend((1..len).map(|_| 3 + r.usize(sp.vocab - 3) as i32));
        for g in 0..group_size {
            reqs.push(GenRequest {
                prompt: prompt.clone(),
                rng: rollout_rng(seed ^ 0x5EED, (task * group_size + g) as u64),
                prompt_key: task as u64,
            });
        }
    }
    reqs
}

fn main() -> anyhow::Result<()> {
    let sp = SchedSpec {
        lanes: 8,
        max_seq: 256,
        vocab: 64,
        d_model: 32,
        pad_id: 0,
        bos_id: 1,
        eos_id: 2,
    };
    let opts = GenOpts { max_new: 96, temperature: 1.0, commit_interval: 32 };
    let (n_tasks, group_size) = (12, 4);
    let reqs = workload(&sp, n_tasks, group_size, 7);
    let buckets = MockBackend::default_buckets(sp.max_seq);
    // EOS pressure tuned so some rollouts end after a handful of tokens
    // while others run to the cap — the mix static batching pads for.
    let eos_bias = 0.08f32;

    // Correctness first: the two paths must agree byte for byte.
    let mut st = GenStats::default();
    let mut ct = GenStats::default();
    let a = run_static_reference(
        &mut MockBackend::new(sp, buckets.clone(), eos_bias),
        &reqs,
        &opts,
        &mut st,
    )?;
    let b = run_continuous(
        &mut MockBackend::new(sp, buckets.clone(), eos_bias),
        &reqs,
        &opts,
        &mut ct,
    )?;
    for (x, y) in a.iter().zip(&b) {
        anyhow::ensure!(
            x.tokens == y.tokens
                && x.sampled_probs == y.sampled_probs
                && x.hidden_rows == y.hidden_rows
                && x.finish == y.finish,
            "continuous output diverged from the static reference"
        );
    }
    let rollouts = reqs.len() as f64;
    println!(
        "workload: {} rollouts ({n_tasks} tasks x {group_size}), completions {}..{} tokens",
        reqs.len(),
        a.iter().map(|g| g.completion_len()).min().unwrap(),
        a.iter().map(|g| g.completion_len()).max().unwrap(),
    );
    println!(
        "static:     {} decode steps, occupancy {:.2}",
        st.decode_steps,
        st.occupancy_frac()
    );
    println!(
        "continuous: {} decode steps, {} prefill calls ({} unique forwards), occupancy {:.2}",
        ct.decode_steps,
        ct.prefill_calls,
        ct.prefill_prompts,
        ct.occupancy_frac()
    );

    let bench = Bencher::default();
    let r_static = bench.run_throughput("static-batch generate", rollouts, "rollouts", || {
        let mut s = GenStats::default();
        run_static_reference(
            &mut MockBackend::new(sp, buckets.clone(), eos_bias),
            &reqs,
            &opts,
            &mut s,
        )
        .unwrap();
    });
    let r_cont = bench.run_throughput("continuous generate", rollouts, "rollouts", || {
        let mut s = GenStats::default();
        run_continuous(
            &mut MockBackend::new(sp, buckets.clone(), eos_bias),
            &reqs,
            &opts,
            &mut s,
        )
        .unwrap();
    });
    let speedup = r_static.mean_ns / r_cont.mean_ns;
    println!(
        "refill speedup: {speedup:.2}x (decode steps {} -> {})",
        st.decode_steps, ct.decode_steps
    );

    let mut rep = BenchReport::new("generation");
    rep.record(&r_static);
    rep.record(&r_cont);
    rep.metric("refill_speedup", speedup);
    rep.metric("decode_steps_static", st.decode_steps as f64);
    rep.metric("decode_steps_continuous", ct.decode_steps as f64);
    rep.metric("prefill_calls", ct.prefill_calls as f64);
    rep.metric("prefill_prompts", ct.prefill_prompts as f64);
    rep.metric("static_occupancy", st.occupancy_frac());
    rep.metric("continuous_occupancy", ct.occupancy_frac());
    let path = rep.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
