//! Fig 11: a single silently-faulty compiled kernel corrupts training.
//! The paper blames torch.compile; our fault model is a miscompiled GRPO
//! backward kernel that drops the positive-advantage clip gate
//! (grpo_step_faulty.hlo.txt — same lowering pipeline, one wrong gate).
//! Clean vs faulty runs from identical base weights.
//!
//!   cargo run --release --bin fig11_compile_fault -- --rl-steps 14

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::runtime::HostTrainState;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{render_table, sparkline, Series};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = RunConfig {
        rl_steps: 12,
        pretrain_steps: 80,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 3,
        max_new_tokens: 12,
        ..Default::default()
    }
    .apply_args(&args);
    // Moderately aggressive lr so the faulty gradient has room to run away.
    cfg.hp.lr *= 10.0;

    println!("== Fig 11: clean vs fault-injected compiled kernel ==");
    let pipeline = SyncPipeline::new(cfg.clone())?;
    let base = pipeline.bootstrap()?;
    let out = Series::default();
    let mut rows = Vec::new();
    for (label, faulty) in [("no-compile (clean kernel)", false), ("torch-compile (faulty kernel)", true)] {
        let p = SyncPipeline::new(cfg.clone())?;
        let state = Box::new(HostTrainState {
            params: base.params.clone(),
            m: base.m.clone(),
            v: base.v.clone(),
            step: 0,
        });
        p.run_rl(state, cfg.rl_steps, "", faulty)?;
        let reward: Vec<f64> = p.series.smoothed("task_reward", 3).iter().map(|x| x.1).collect();
        let ratio: Vec<f64> = p.series.get("ratio_max").iter().map(|x| x.1).collect();
        let key = if faulty { "faulty" } else { "clean" };
        for (i, (r, rm)) in reward.iter().zip(&ratio).enumerate() {
            out.push(i as u64, &format!("{key}_task_reward"), *r);
            out.push(i as u64, &format!("{key}_ratio_max"), *rm);
        }
        rows.push(vec![
            label.to_string(),
            format!("{:.3}", reward.last().unwrap_or(&0.0)),
            format!("{:.1}", ratio.iter().cloned().fold(0.0f64, f64::max)),
            sparkline(&reward),
        ]);
    }
    println!(
        "{}",
        render_table(&["kernel", "final reward", "max ratio seen", "reward trajectory"], &rows)
    );
    println!("(paper: the compiled run collapses while no-compile stays stable; \
              here the faulty backward lets probability ratios run away)");
    out.save("runs/fig11_compile_fault.jsonl")?;
    println!("series written to runs/fig11_compile_fault.jsonl");
    Ok(())
}
