# Build-time entry points. The request path is pure Rust over the AOT
# artifacts; Python only runs here.

# Lower every model size's computations to HLO text + spec.json under
# artifacts/<size>/ (the contract runtime/spec.rs binds).
#
# REGENERATE AFTER PULLING THE CONTINUOUS-BATCHING ENGINE: the rollout
# scheduler (rust/src/runtime/scheduler.rs, `gen-refill` knob) binds two
# artifact additions —
#   * decode_step now takes a vectored per-lane `pos: i32[batch_infer]`
#     (lanes retire on EOS and refill independently, so they are no
#     longer position-synchronized), and
#   * a `prefill_kv_{T}` ladder (T = powers of two from the TOPLOC commit
#     interval through max_seq) that prefills prompts straight into the
#     decode KV cache with lane routing for GRPO group sharing.
# Artifact sets lowered before this contract lack both; the runtime
# detects that (ModelSpec::supports_continuous) and falls back to the
# static reference engine, so nothing breaks — but the refill speedup
# only exists after `make artifacts`.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# Python-side unit tests (model numerics, AOT signatures, kernels).
pytest:
	cd python && python3 -m pytest tests/ -q

# Tier-1 gate (see ROADMAP.md).
tier1:
	cd rust && cargo build --release && cargo test -q

# swarmlint: the from-scratch determinism / slashability gate over the
# trust-critical modules (rust/src/analysis; rules documented there).
# Binding in CI — run it locally before pushing.
lint:
	cd rust && cargo run --release --bin swarmlint

.PHONY: artifacts pytest tier1 lint
