"""L2: the policy model and its training/inference computations, in JAX.

Everything in this file is *build-time only*: `aot.py` lowers the jitted
entrypoints to HLO text once, and the Rust coordinator executes the artifacts
via PJRT. No Python runs on any request or training path.

Computations exported (see aot.py / DESIGN.md for the artifact table):
  init_params     seed -> params
  pretrain_step   next-token CE + Adam (e2e pretraining of the base model)
  grpo_step       the paper's GRPO recipe: token-level two-sided-clip loss
                  (L1 Pallas kernel), KL + entropy aux losses, global-norm
                  gradient clipping, Adam — one fused optimizer step
  logprobs        per-token logprobs + entropy under the current policy
                  (the trainer recomputes old_lp at optimization start,
                  paper §2.1.1)
  prefill         full-sequence logits + final hidden states (TOPLOC
                  validator prefill, sampling checks)
  decode_step     single-token KV-cache decode with per-lane positions
                  (rollout generation under the continuous scheduler)
  prefill_kv      bucketed prompt prefill straight into the decode KV
                  cache, with lane routing for GRPO group sharing

Sequence packing (paper §4.1): every train-path computation takes a
`segs [B,T] i32` array; attention is block-diagonal over segments
(seg id 0 = padding), which is exactly the paper's "adapting the attention
mask and collating samples into the sequence dimension".
"""

import functools

import jax
import jax.numpy as jnp

from . import config as C
from .kernels import grpo_loss


# ---------------------------------------------------------------------------
# Parameters


def unflatten(cfg: C.ModelConfig, flat):
    """Flat list (canonical order, cfg.param_specs) -> name->array dict."""
    specs = cfg.param_specs()
    assert len(flat) == len(specs), (len(flat), len(specs))
    return {name: x for (name, _), x in zip(specs, flat)}


def init_params(cfg: C.ModelConfig, seed):
    """Deterministic init from a u32 seed (lowered into init.hlo.txt)."""
    key = jax.random.PRNGKey(seed)
    out = []
    resid_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
    for i, (name, shape) in enumerate(cfg.param_specs()):
        k = jax.random.fold_in(key, i)
        base = name.split(".")[-1]
        if base in ("ln1_g", "ln2_g", "lnf_g"):
            x = jnp.ones(shape, jnp.float32)
        elif base in ("ln1_b", "ln2_b", "lnf_b", "b1", "b2"):
            x = jnp.zeros(shape, jnp.float32)
        elif base in ("wo", "w2"):
            x = jax.random.normal(k, shape, jnp.float32) * resid_scale
        elif base == "pos_emb":
            x = jax.random.normal(k, shape, jnp.float32) * 0.01
        else:
            x = jax.random.normal(k, shape, jnp.float32) * 0.02
        out.append(x)
    return out


# ---------------------------------------------------------------------------
# Forward pass


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _unheads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def forward(cfg: C.ModelConfig, flat_params, tokens, segs,
            use_pallas_attention: bool = False):
    """Returns (logits [B,T,V], hidden [B,T,D]).

    Attention mask: causal AND same-segment (block-diagonal packing mask).
    seg id 0 marks padding: those keys are masked out everywhere.
    """
    p = unflatten(cfg, flat_params)
    b, t = tokens.shape
    # Position ids reset at every segment boundary so a packed sample sees
    # the same positions it would unpacked (paper §4.1 packing integrity).
    t_idx = jnp.arange(t, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((b, 1), bool), segs[:, 1:] != segs[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(
        jnp.where(change, t_idx[None, :], 0), axis=1)
    pos = t_idx[None, :] - seg_start
    x = p["tok_emb"][tokens] + p["pos_emb"][pos]

    causal = jnp.tril(jnp.ones((t, t), bool))
    same_seg = (segs[:, :, None] == segs[:, None, :]) & (segs[:, None, :] != 0)
    mask = causal[None] & same_seg  # [B,T,T]
    neg = jnp.asarray(-1e30, jnp.float32)

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        q = _heads(h @ p[pre + "wq"], cfg.n_heads)
        k = _heads(h @ p[pre + "wk"], cfg.n_heads)
        v = _heads(h @ p[pre + "wv"], cfg.n_heads)
        if use_pallas_attention:
            # Packing mask unsupported in the blocked kernel: callers lower
            # this variant only for unpacked (single-segment) batches.
            from .kernels import attention as attn_k
            o = attn_k.mha(q, k, v, block_q=cfg.attn_block_q,
                           block_k=cfg.attn_block_k)
        else:
            scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
            s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
            s = jnp.where(mask[:, None], s, neg)
            o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, axis=-1), v)
        x = x + _unheads(o) @ p[pre + "wo"]
        h = _layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        h = jax.nn.gelu(h @ p[pre + "w1"] + p[pre + "b1"])
        x = x + h @ p[pre + "w2"] + p[pre + "b2"]

    hidden = _layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = hidden @ p["tok_emb"].T  # tied embeddings
    return logits, hidden


def token_logprobs(cfg, flat_params, tokens, segs):
    """lp[b,t] = log p(tokens[t] | tokens[<t]) for t>=1 (0 at t=0), plus the
    per-position predictive entropy (aligned like lp) and validity mask."""
    logits, _ = forward(cfg, flat_params, tokens, segs)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)  # predicts t=1..T-1
    tgt = tokens[:, 1:]
    lp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    probs = jnp.exp(logp)
    ent = -jnp.sum(probs * logp, axis=-1)
    zero = jnp.zeros((tokens.shape[0], 1), jnp.float32)
    lp = jnp.concatenate([zero, lp], axis=1)
    ent = jnp.concatenate([zero, ent], axis=1)
    valid = (segs[:, 1:] != 0) & (segs[:, 1:] == segs[:, :-1])
    valid = jnp.concatenate([jnp.zeros_like(zero, bool), valid], axis=1)
    return lp, ent, valid


# ---------------------------------------------------------------------------
# Adam + gradient clipping


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in grads))


def adam_update(params, m, v, grads, step, lr, grad_clip):
    """Global-norm clip (paper §3.5: aggressive thresholds 0.05-0.1) + Adam."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
    t = step + 1.0
    bc1 = 1.0 - C.ADAM_B1 ** t
    bc2 = 1.0 - C.ADAM_B2 ** t
    new_p, new_m, new_v = [], [], []
    for pp, mm, vv, gg in zip(params, m, v, grads):
        gg = gg * scale
        mm = C.ADAM_B1 * mm + (1.0 - C.ADAM_B1) * gg
        vv = C.ADAM_B2 * vv + (1.0 - C.ADAM_B2) * gg * gg
        upd = (mm / bc1) / (jnp.sqrt(vv / bc2) + C.ADAM_EPS)
        new_p.append(pp - lr * upd)
        new_m.append(mm)
        new_v.append(vv)
    return new_p, new_m, new_v, gnorm


# ---------------------------------------------------------------------------
# Pretraining step (next-token CE)


def pretrain_step(cfg, params, m, v, step, tokens, segs, hp):
    """hp: f32[2] = [lr, grad_clip]. Returns (params', m', v', loss, gnorm)."""

    def loss_fn(ps):
        lp, _, valid = token_logprobs(cfg, ps, tokens, segs)
        w = valid.astype(jnp.float32)
        return -jnp.sum(lp * w) / jnp.maximum(jnp.sum(w), 1.0)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_p, new_m, new_v, gnorm = adam_update(
        params, m, v, grads, step, hp[0], hp[1])
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, gnorm)


# ---------------------------------------------------------------------------
# GRPO step (the paper's recipe, §3.4 + §4.1)


def grpo_step(cfg, params, m, v, step, tokens, segs, loss_mask, adv, old_lp,
              hp, faulty: bool = False):
    """One fused GRPO optimizer micro-step.

    tokens/segs/loss_mask/adv/old_lp: [B,T] (packed rollouts; adv already
    broadcast per-token by the Rust batcher). hp: f32[8], see config.HP_LEN.

    Loss = -(token-level two-sided-clip objective)            (Pallas kernel)
           + kl_coef * KL(pi_theta || pi_old)  (k3 estimator)
           - ent_coef * entropy
    Token-level normalization (DAPO / Dr. GRPO): sum over tokens / n_tokens,
    not per-sample means.

    Returns params' + m' + v' + metrics f32[7]:
      [loss, gnorm, clipfrac, entropy, kl, ratio_max, obj_mean]
    """
    lr, grad_clip = hp[0], hp[1]
    eps, delta = hp[2], hp[3]
    kl_coef, ent_coef = hp[4], hp[5]
    wsum = jnp.maximum(jnp.sum(loss_mask), 1.0)

    def loss_fn(ps):
        lp, ent, _ = token_logprobs(cfg, ps, tokens, segs)
        obj = grpo_loss.grpo_objective(
            lp, old_lp, adv, loss_mask, eps, delta,
            block_rows=cfg.grpo_block_rows, faulty=faulty)
        pg_loss = -jnp.sum(obj) / wsum
        # k3 KL estimator vs the rollout policy (paper adds an auxiliary KL).
        logr = (old_lp - lp) * loss_mask
        kl = jnp.sum((jnp.exp(logr) - 1.0 - logr) * loss_mask) / wsum
        ent_mean = jnp.sum(ent * loss_mask) / wsum
        total = pg_loss + kl_coef * kl - ent_coef * ent_mean
        return total, (lp, kl, ent_mean)

    (loss, (lp, kl, ent_mean)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params)
    new_p, new_m, new_v, gnorm = adam_update(
        params, m, v, grads, step, lr, grad_clip)

    obj, clip_ind, ratio = grpo_loss.grpo_stats(
        lp, old_lp, adv, loss_mask, eps, delta,
        block_rows=cfg.grpo_block_rows)
    clipfrac = jnp.sum(clip_ind) / wsum
    ratio_max = jnp.max(ratio)
    obj_mean = jnp.sum(obj) / wsum
    metrics = jnp.stack([loss, gnorm, clipfrac, ent_mean, kl, ratio_max,
                         obj_mean])
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (metrics,)


# ---------------------------------------------------------------------------
# Inference: prefill + single-token KV-cache decode


def prefill(cfg, params, tokens):
    """Unpacked full-sequence forward for the TOPLOC validator: logits +
    final hidden states for every position. PAD (id 0) tokens are masked."""
    segs = (tokens != C.PAD_ID).astype(jnp.int32)
    logits, hidden = forward(cfg, params, tokens, segs)
    return logits, hidden


def kv_shape(cfg):
    return (cfg.n_layers, 2, cfg.batch_infer, cfg.max_seq, cfg.d_model)


def decode_step(cfg, flat_params, kv, tok, pos):
    """One autoregressive step with a KV cache.

    kv: f32[L,2,B,T,D]; tok: i32[B] (token at position `pos[b]` of lane b);
    pos: i32[B] — **per-lane** positions. Under the continuous-batching
    scheduler (rust runtime/scheduler.rs) lanes retire on EOS and refill
    with fresh prompts, so they are no longer position-synchronized; the
    static reference path simply passes a constant vector.
    Returns (logits [B,V], hidden [B,D], kv').

    The Rust SampleEngine feeds PJRT buffers back in without host round trips
    (runtime/engine.rs), so the cache never leaves the device.
    """
    p = unflatten(cfg, flat_params)
    b = tok.shape[0]
    t = cfg.max_seq
    lanes = jnp.arange(b)
    x = p["tok_emb"][tok] + p["pos_emb"][pos]  # [B,D]

    pos_mask = (jnp.arange(t)[None, :] <= pos[:, None])[:, None, :]  # [B,1,T]
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        q = h @ p[pre + "wq"]  # [B,D]
        k = h @ p[pre + "wk"]
        vv = h @ p[pre + "wv"]
        # Per-lane scatter: lane b writes its k/v at its own position.
        kv = kv.at[i, 0, lanes, pos].set(k)
        kv = kv.at[i, 1, lanes, pos].set(vv)
        keys = kv[i, 0]  # [B,T,D]
        vals = kv[i, 1]
        qh = q.reshape(b, cfg.n_heads, cfg.d_head)
        kh = keys.reshape(b, t, cfg.n_heads, cfg.d_head)
        vh = vals.reshape(b, t, cfg.n_heads, cfg.d_head)
        s = jnp.einsum("bhd,bthd->bht", qh, kh) * scale
        s = jnp.where(pos_mask, s, -1e30)
        probs = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bht,bthd->bhd", probs, vh).reshape(b, cfg.d_model)
        x = x + o @ p[pre + "wo"]
        h = _layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        h = jax.nn.gelu(h @ p[pre + "w1"] + p[pre + "b1"])
        x = x + h @ p[pre + "w2"] + p[pre + "b2"]

    hidden = _layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = hidden @ p["tok_emb"].T
    return logits, hidden, kv


def prefill_kv(cfg, flat_params, kv, tokens, lane_src, lane_mask):
    """Prompt prefill into the decode KV cache (continuous batching).

    tokens: i32[B,Tb] — up to B *unique* prompt rows, PAD-padded to the
    bucket length Tb; lane_src: i32[B] — which computed row lane l's KV
    comes from (GRPO group sharing: one prompt forward, its per-layer k/v
    projections replicated across the group's lanes); lane_mask: f32[B] —
    1.0 installs into lane l, 0.0 leaves that lane's cache untouched (it
    may hold a live sequence).

    Returns (logits [B,Tb,V], hidden [B,Tb,D], kv'). Positions at/after a
    row's true prompt length hold pad-derived values in both the outputs
    and the installed KV; the decode path overwrites each position before
    ever attending to it, so they are never observed.

    Equivalence contract: for prompt positions, logits/hidden/k/v match
    what `decode_step` would produce feeding the prompt token by token —
    prompts are single segments anchored at position 0 (positions are
    plain 0..Tb-1, the same `pos_emb` rows the decode path uses) and
    queries attend causally to non-PAD keys only.
    """
    p = unflatten(cfg, flat_params)
    b, tb = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][jnp.arange(tb)][None]

    causal = jnp.tril(jnp.ones((tb, tb), bool))
    nonpad = tokens != C.PAD_ID
    mask = causal[None] & nonpad[:, None, :]  # [B,Tb,Tb]
    neg = jnp.asarray(-1e30, jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    sel = lane_mask[:, None, None] > 0.5  # [B,1,1]

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        kf = h @ p[pre + "wk"]  # [B,Tb,D] — the decode path's cache rows
        vf = h @ p[pre + "wv"]
        # Install: lane l receives row lane_src[l]'s projections at
        # positions 0..Tb; unmasked lanes keep their existing cache.
        kv = kv.at[i, 0, :, :tb, :].set(
            jnp.where(sel, kf[lane_src], kv[i, 0, :, :tb, :]))
        kv = kv.at[i, 1, :, :tb, :].set(
            jnp.where(sel, vf[lane_src], kv[i, 1, :, :tb, :]))
        q = _heads(h @ p[pre + "wq"], cfg.n_heads)
        k = _heads(kf, cfg.n_heads)
        v = _heads(vf, cfg.n_heads)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
        s = jnp.where(mask[:, None], s, neg)
        o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, axis=-1), v)
        x = x + _unheads(o) @ p[pre + "wo"]
        h = _layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        h = jax.nn.gelu(h @ p[pre + "w1"] + p[pre + "b1"])
        x = x + h @ p[pre + "w2"] + p[pre + "b2"]

    hidden = _layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = hidden @ p["tok_emb"].T
    return logits, hidden, kv


def attn_demo(cfg, q, k, v):
    """Standalone lowering of the Pallas attention kernel (composability
    proof executed from Rust; see rust/tests/runtime_attn.rs)."""
    from .kernels import attention as attn_k
    return attn_k.mha(q, k, v, block_q=cfg.attn_block_q,
                      block_k=cfg.attn_block_k)
