"""Model/compile-time configuration shared across L1/L2 and exported to L3.

The Rust coordinator never imports this; `aot.py` serializes every field it
needs into `artifacts/<size>/spec.json`.
"""

from dataclasses import dataclass, field, asdict


# Token vocabulary. The authoritative tokenizer lives in the Rust layer
# (rust/src/data/tokenizer.rs); python only needs the size and the ids of
# the special tokens used inside lowered computations.
VOCAB_SIZE = 64
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2


@dataclass
class ModelConfig:
    """GPT-style decoder-only transformer (pre-LN, tied embeddings)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    max_seq: int = 256
    vocab: int = VOCAB_SIZE
    # Static batch shapes baked into the AOT artifacts.
    batch_train: int = 8
    batch_infer: int = 16
    # L1 kernel block schedule (see DESIGN.md §Perf / EXPERIMENTS.md §Perf).
    grpo_block_rows: int = 8
    attn_block_q: int = 64
    attn_block_k: int = 128

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_specs(self):
        """Flat parameter list: (name, shape) in the canonical order used by
        every lowered artifact and by the Rust ParamStore."""
        d, v, t = self.d_model, self.vocab, self.max_seq
        specs = [("tok_emb", (v, d)), ("pos_emb", (t, d))]
        for i in range(self.n_layers):
            p = f"layer{i}."
            specs += [
                (p + "ln1_g", (d,)),
                (p + "ln1_b", (d,)),
                (p + "wq", (d, d)),
                (p + "wk", (d, d)),
                (p + "wv", (d, d)),
                (p + "wo", (d, d)),
                (p + "ln2_g", (d,)),
                (p + "ln2_b", (d,)),
                (p + "w1", (d, 4 * d)),
                (p + "b1", (4 * d,)),
                (p + "w2", (4 * d, d)),
                (p + "b2", (d,)),
            ]
        specs += [("lnf_g", (d,)), ("lnf_b", (d,))]
        return specs

    def n_params(self) -> int:
        total = 0
        for _, shape in self.param_specs():
            n = 1
            for s in shape:
                n *= s
            total += n
        return total

    def to_dict(self):
        return asdict(self)


# Size registry. The paper trains a 32 B model on an H100 cluster plus a
# permissionless GPU swarm; on this 1-CPU testbed we reproduce the *system*
# with scaled-down models (DESIGN.md §Hardware-Adaptation). `xl` documents
# the 100M-class configuration; it lowers fine but is not run by default.
SIZES = {
    "nano": ModelConfig("nano", d_model=64, n_layers=2, n_heads=2),
    "micro": ModelConfig("micro", d_model=128, n_layers=4, n_heads=4),
    "small": ModelConfig("small", d_model=192, n_layers=6, n_heads=6, batch_train=4, batch_infer=8),
    "medium": ModelConfig("medium", d_model=320, n_layers=8, n_heads=8, batch_train=4, batch_infer=8),
    "xl": ModelConfig("xl", d_model=768, n_layers=12, n_heads=12, batch_train=2, batch_infer=4),
}

# Adam hyperparameters baked into the lowered optimizer (paper §4.1 uses
# standard Adam; lr / grad-clip / GRPO hps stay *runtime inputs*).
ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8

# Runtime-supplied hyperparameter vector layout for grpo_step (f32[8]):
#   [0] lr  [1] grad_clip  [2] eps (GRPO clip)  [3] delta (two-sided cap)
#   [4] kl_coef  [5] ent_coef  [6..7] reserved
HP_LEN = 8
# pretrain_step hp vector (f32[2]): [0] lr  [1] grad_clip
PRETRAIN_HP_LEN = 2

# TOPLOC commitment interval in tokens (paper §2.1.2: hash every 32 tokens).
TOPLOC_INTERVAL = 32
TOPLOC_TOPK = 8
