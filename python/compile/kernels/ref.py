"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package is checked against these references by
`python/tests/` (hypothesis sweeps over shapes/values) before the lowered
artifacts are trusted by the Rust runtime.
"""

import jax
import jax.numpy as jnp


def grpo_objective_ref(lp_new, lp_old, adv, mask, eps, delta):
    """Token-level two-sided-clip GRPO objective (paper §3.4).

    obj = min( min(r, delta) * A , clip(r, 1-eps, 1+eps) * A ) * mask
    with r = exp(lp_new - lp_old).

    Returns (obj, clipped_indicator, ratio), all masked.
    """
    r = jnp.exp(lp_new - lp_old)
    capped = jnp.minimum(r, delta) * adv
    clipped = jnp.clip(r, 1.0 - eps, 1.0 + eps) * adv
    obj = jnp.minimum(capped, clipped)
    pos_clip = (adv > 0) & (r > 1.0 + eps)
    neg_clip = (adv < 0) & ((r < 1.0 - eps) | (r > delta))
    ind = jnp.where(pos_clip | neg_clip, 1.0, 0.0)
    return obj * mask, ind * mask, r * mask


def grpo_grad_ref(lp_new, lp_old, adv, mask, eps, delta):
    """Analytic d(obj)/d(lp_new): r*A gated by the active (unclipped) branch."""
    r = jnp.exp(lp_new - lp_old)
    gate_pos = (r <= 1.0 + eps).astype(lp_new.dtype)
    gate_neg = ((r >= 1.0 - eps) & (r <= delta)).astype(lp_new.dtype)
    gate = jnp.where(adv > 0, gate_pos, gate_neg)
    return r * adv * gate * mask


def grpo_grad_autodiff_ref(lp_new, lp_old, adv, mask, eps, delta):
    """Same gradient via jax.grad over the pure-jnp objective (sanity on the
    analytic derivation; min/clip kinks are measure-zero for test inputs)."""

    def s(lp):
        obj, _, _ = grpo_objective_ref(lp, lp_old, adv, mask, eps, delta)
        return jnp.sum(obj)

    return jax.grad(s)(lp_new)


def attention_ref(q, k, v, causal=True):
    """Plain causal multi-head attention. q,k,v: [B, H, T, Dh]."""
    t = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
