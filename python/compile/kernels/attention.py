"""L1 Pallas kernel: blocked causal multi-head attention (flash-style).

This is the TPU re-think of the paper's GPU inference hot path (vLLM paged
attention / FlashAttention on the rollout workers): instead of threadblocks
and shared memory, the HBM<->VMEM schedule is expressed with BlockSpecs — a
(block_q, d_head) query tile streams against (block_k, d_head) KV tiles with
an online-softmax accumulator carried in registers/VMEM.

VMEM budget per grid step (DESIGN.md §Hardware-Adaptation):
  q tile      block_q * d_head * 4 B
  k/v tiles   2 * T * d_head * 4 B        (full-T resident at T=256; for
                                            longer T shrink the KV BlockSpec)
  accumulator block_q * d_head * 4 B
At the default nano/micro shapes this is < 200 KiB, i.e. deeply
double-bufferable against the ~16 MiB VMEM of a TPU core.

interpret=True (CPU PJRT cannot run Mosaic); validated against
kernels/ref.py:attention_ref by pytest, including a lowered-artifact round
trip executed from Rust (rust/tests/runtime_attn.rs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_len: int,
                 causal: bool):
    block_q, d_head = q_ref.shape
    qblk = pl.program_id(1)
    q = q_ref[...] * (1.0 / jnp.sqrt(jnp.asarray(d_head, jnp.float32)))
    q_idx = qblk * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    n_kblocks = seq_len // block_k

    def body(i, carry):
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        s = q @ k.T  # [block_q, block_k]
        if causal:
            k_idx = i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(q_idx >= k_idx, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d_head), jnp.float32)
    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_kblocks, body, (acc0, m0, l0))
    o_ref[...] = acc / jnp.maximum(l_i, 1e-30)


def mha(q, k, v, *, block_q: int = 64, block_k: int = 128, causal: bool = True):
    """Blocked causal attention. q,k,v: [B, H, T, Dh] (f32). Returns [B,H,T,Dh].

    T must be divisible by block_q and block_k (pad upstream if not).
    """
    b, h, t, dh = q.shape
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)
    qf = q.reshape(b * h, t, dh).astype(jnp.float32)
    kf = k.reshape(b * h, t, dh).astype(jnp.float32)
    vf = v.reshape(b * h, t, dh).astype(jnp.float32)

    grid = (b * h, t // block_q)
    kern = functools.partial(_attn_kernel, block_k=block_k, seq_len=t,
                             causal=causal)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((None, t, dh), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((None, t, dh), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, t, dh).astype(q.dtype)
