"""L1 Pallas kernel: fused token-level two-sided-clip GRPO objective.

This is the compute hot-spot of the paper's training recipe (§3.4): for every
packed token, compute the probability ratio, apply the asymmetric two-sided
clipping (epsilon on the trust region, delta capping negative-advantage
updates), and emit the masked objective plus clip diagnostics — in one fused
pass over VMEM-resident blocks.

TPU shaping (DESIGN.md §Hardware-Adaptation): the [B, T] token grid is
flattened and retiled to (rows, 128) lanes; each grid step processes a
(block_rows, 128) tile — all operands resident in VMEM
(7 arrays * block_rows * 128 * 4 B ≈ 28 KiB at block_rows=8, far under the
~16 MiB VMEM budget, leaving room for double buffering). The backward pass is
a second fused kernel wired via jax.custom_vjp, so autodiff never traces the
kernel interior.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against kernels/ref.py by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _fwd_kernel(lp_new_ref, lp_old_ref, adv_ref, mask_ref, hp_ref,
                obj_ref, clip_ref, ratio_ref):
    eps = hp_ref[0]
    delta = hp_ref[1]
    lpn = lp_new_ref[...]
    lpo = lp_old_ref[...]
    a = adv_ref[...]
    m = mask_ref[...]
    r = jnp.exp(lpn - lpo)
    capped = jnp.minimum(r, delta) * a
    clipped = jnp.clip(r, 1.0 - eps, 1.0 + eps) * a
    obj = jnp.minimum(capped, clipped)
    pos_clip = (a > 0) & (r > 1.0 + eps)
    neg_clip = (a < 0) & ((r < 1.0 - eps) | (r > delta))
    obj_ref[...] = obj * m
    clip_ref[...] = jnp.where(pos_clip | neg_clip, 1.0, 0.0) * m
    ratio_ref[...] = r * m


def _bwd_kernel(lp_new_ref, lp_old_ref, adv_ref, mask_ref, hp_ref, g_ref,
                dlp_ref, *, faulty: bool):
    eps = hp_ref[0]
    delta = hp_ref[1]
    lpn = lp_new_ref[...]
    lpo = lp_old_ref[...]
    a = adv_ref[...]
    m = mask_ref[...]
    r = jnp.exp(lpn - lpo)
    if faulty:
        # Fig 11 fault model: a miscompiled kernel that silently drops the
        # positive-advantage clip gate — gradients keep pushing probability
        # ratios upward past 1+eps, which is exactly the kind of "single
        # faulty kernel" the paper blames for the torch.compile collapse.
        gate_pos = jnp.ones_like(r)
    else:
        gate_pos = (r <= 1.0 + eps).astype(r.dtype)
    gate_neg = ((r >= 1.0 - eps) & (r <= delta)).astype(r.dtype)
    gate = jnp.where(a > 0, gate_pos, gate_neg)
    dlp_ref[...] = g_ref[...] * r * a * gate * m


def _tile(x, rows):
    """[N] -> [rows_total, LANES], zero-padded."""
    n = x.shape[0]
    rows_total = pl.cdiv(n, LANES)
    pad = rows_total * LANES - n
    x = jnp.pad(x, (0, pad))
    del rows
    return x.reshape(rows_total, LANES), n


def _grid_call(kernel, outs, inputs, block_rows):
    rows_total = inputs[0].shape[0]
    grid = (pl.cdiv(rows_total, block_rows),)
    tile_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    hp_spec = pl.BlockSpec((8,), lambda i: (0,))
    in_specs = [tile_spec] * (len(inputs) - 1) + [hp_spec]
    # hp vector is the last input in our calling convention; reorder so the
    # kernel signature (lp_new, lp_old, adv, mask, hp, [g]) holds.
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[tile_spec] * len(outs),
        out_shape=[jax.ShapeDtypeStruct((rows_total, LANES), jnp.float32) for _ in outs],
        interpret=True,
    )(*inputs)


def _run_fwd(lp_new, lp_old, adv, mask, hp, block_rows):
    shape = lp_new.shape
    flat = [x.reshape(-1).astype(jnp.float32) for x in (lp_new, lp_old, adv, mask)]
    tiled = []
    n = flat[0].shape[0]
    for x in flat:
        t, n = _tile(x, block_rows)
        tiled.append(t)
    obj, clip, ratio = _grid_call(
        _fwd_kernel, ("obj", "clip", "ratio"), tiled + [hp], block_rows)
    unpack = lambda t: t.reshape(-1)[:n].reshape(shape)
    return unpack(obj), unpack(clip), unpack(ratio)


def _run_bwd(lp_new, lp_old, adv, mask, hp, g, block_rows, faulty):
    shape = lp_new.shape
    flat = [x.reshape(-1).astype(jnp.float32) for x in (lp_new, lp_old, adv, mask)]
    gflat = g.reshape(-1).astype(jnp.float32)
    tiled = []
    n = flat[0].shape[0]
    for x in flat:
        t, n = _tile(x, block_rows)
        tiled.append(t)
    gt, _ = _tile(gflat, block_rows)
    kern = functools.partial(_bwd_kernel, faulty=faulty)

    rows_total = tiled[0].shape[0]
    grid = (pl.cdiv(rows_total, block_rows),)
    tile_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    hp_spec = pl.BlockSpec((8,), lambda i: (0,))
    (dlp,) = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tile_spec] * 4 + [hp_spec, tile_spec],
        out_specs=[tile_spec],
        out_shape=[jax.ShapeDtypeStruct((rows_total, LANES), jnp.float32)],
        interpret=True,
    )(*tiled, hp, gt)
    return dlp.reshape(-1)[: g.size].reshape(shape)


def _make_objective(block_rows: int, faulty: bool):
    @jax.custom_vjp
    def objective(lp_new, lp_old, adv, mask, hp):
        obj, _, _ = _run_fwd(lp_new, lp_old, adv, mask, hp, block_rows)
        return obj

    def fwd(lp_new, lp_old, adv, mask, hp):
        obj, _, _ = _run_fwd(lp_new, lp_old, adv, mask, hp, block_rows)
        return obj, (lp_new, lp_old, adv, mask, hp)

    def bwd(res, g):
        lp_new, lp_old, adv, mask, hp = res
        dlp = _run_bwd(lp_new, lp_old, adv, mask, hp, g, block_rows, faulty)
        return (dlp, jnp.zeros_like(lp_old), jnp.zeros_like(adv),
                jnp.zeros_like(mask), jnp.zeros_like(hp))

    objective.defvjp(fwd, bwd)
    return objective


@functools.lru_cache(maxsize=None)
def objective_fn(block_rows: int = 8, faulty: bool = False):
    """Differentiable fused GRPO objective.

    objective(lp_new[B,T], lp_old, adv, mask, hp[f32[8] with hp[0]=eps,
    hp[1]=delta]) -> masked per-token objective [B,T].
    """
    return _make_objective(block_rows, faulty)


def grpo_objective(lp_new, lp_old, adv, mask, eps, delta,
                   block_rows: int = 8, faulty: bool = False):
    """Convenience wrapper taking eps/delta as (traced) scalars."""
    hp = jnp.zeros((8,), jnp.float32).at[0].set(eps).at[1].set(delta)
    return objective_fn(block_rows, faulty)(lp_new, lp_old, adv, mask, hp)


def grpo_stats(lp_new, lp_old, adv, mask, eps, delta, block_rows: int = 8):
    """Non-differentiable diagnostics from the same fused forward kernel:
    (objective, clip indicator, ratio), all masked."""
    hp = jnp.zeros((8,), jnp.float32).at[0].set(eps).at[1].set(delta)
    return _run_fwd(jax.lax.stop_gradient(lp_new), lp_old, adv, mask, hp,
                    block_rows)
