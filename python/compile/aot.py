"""AOT entrypoint: lower every L2 computation to HLO text + emit spec.json.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config as C
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(cfg):
    return [_spec(s) for _, s in cfg.param_specs()]


def _sig(names_shapes):
    return [
        {"name": n, "shape": list(s), "dtype": d}
        for (n, s, d) in names_shapes
    ]


def prefill_ladder(max_seq: int):
    """Bucketed prefill lengths: powers of two from TOPLOC's commit
    interval (the smallest useful frame — commitments land on interval
    boundaries) up to, but excluding, the full frame."""
    t, out = max(C.TOPLOC_INTERVAL, 16), []
    while t < max_seq:
        out.append(t)
        t *= 2
    return out


def artifact_defs(cfg: C.ModelConfig):
    """(name, fn, example_args, input_signature, output_signature) tuples."""
    bt, bi, t, v, d = (cfg.batch_train, cfg.batch_infer, cfg.max_seq,
                       cfg.vocab, cfg.d_model)
    pspecs = _param_specs(cfg)
    pnames = [n for n, _ in cfg.param_specs()]
    psig = [(f"param:{n}", s, "f32") for n, s in cfg.param_specs()]
    msig = [(f"adam_m:{n}", s, "f32") for n, s in cfg.param_specs()]
    vsig = [(f"adam_v:{n}", s, "f32") for n, s in cfg.param_specs()]

    defs = []

    # --- init ---
    defs.append((
        "init",
        lambda seed: tuple(M.init_params(cfg, seed)),
        [_spec((), jnp.uint32)],
        _sig([("seed", (), "u32")]),
        _sig(psig),
    ))

    # --- pretrain_step ---
    def pre_fn(*args):
        n = len(pspecs)
        params, m, v_ = args[:n], args[n:2 * n], args[2 * n:3 * n]
        step, tokens, segs, hp = args[3 * n:]
        return M.pretrain_step(cfg, list(params), list(m), list(v_), step,
                               tokens, segs, hp)

    pre_args = (pspecs * 3) + [
        _spec(()), _spec((bt, t), jnp.int32), _spec((bt, t), jnp.int32),
        _spec((C.PRETRAIN_HP_LEN,)),
    ]
    defs.append((
        "pretrain_step", pre_fn, pre_args,
        _sig(psig + msig + vsig + [
            ("step", (), "f32"), ("tokens", (bt, t), "i32"),
            ("segs", (bt, t), "i32"), ("hp", (C.PRETRAIN_HP_LEN,), "f32"),
        ]),
        _sig(psig + msig + vsig + [("loss", (), "f32"), ("gnorm", (), "f32")]),
    ))

    # --- grpo_step (+ fault-injected variant for Fig 11) ---
    def grpo_fn(faulty, *args):
        n = len(pspecs)
        params, m, v_ = args[:n], args[n:2 * n], args[2 * n:3 * n]
        step, tokens, segs, loss_mask, adv, old_lp, hp = args[3 * n:]
        return M.grpo_step(cfg, list(params), list(m), list(v_), step, tokens,
                           segs, loss_mask, adv, old_lp, hp, faulty=faulty)

    grpo_args = (pspecs * 3) + [
        _spec(()), _spec((bt, t), jnp.int32), _spec((bt, t), jnp.int32),
        _spec((bt, t)), _spec((bt, t)), _spec((bt, t)), _spec((C.HP_LEN,)),
    ]
    grpo_in = _sig(psig + msig + vsig + [
        ("step", (), "f32"), ("tokens", (bt, t), "i32"),
        ("segs", (bt, t), "i32"), ("loss_mask", (bt, t), "f32"),
        ("advantages", (bt, t), "f32"), ("old_logprobs", (bt, t), "f32"),
        ("hp", (C.HP_LEN,), "f32"),
    ])
    grpo_out = _sig(psig + msig + vsig + [("metrics", (7,), "f32")])
    defs.append(("grpo_step", functools.partial(grpo_fn, False), grpo_args,
                 grpo_in, grpo_out))
    if cfg.name == "nano":
        defs.append(("grpo_step_faulty", functools.partial(grpo_fn, True),
                     grpo_args, grpo_in, grpo_out))

    # --- logprobs ---
    def lp_fn(*args):
        n = len(pspecs)
        params = list(args[:n])
        tokens, segs = args[n:]
        lp, ent, valid = M.token_logprobs(cfg, params, tokens, segs)
        return lp, ent, valid.astype(jnp.float32)

    defs.append((
        "logprobs", lp_fn,
        pspecs + [_spec((bt, t), jnp.int32), _spec((bt, t), jnp.int32)],
        _sig(psig + [("tokens", (bt, t), "i32"), ("segs", (bt, t), "i32")]),
        _sig([("logprobs", (bt, t), "f32"), ("entropy", (bt, t), "f32"),
              ("valid", (bt, t), "f32")]),
    ))

    # --- prefill (validator; inference-batch shaped) ---
    # The full [bi, max_seq] frame plus a ladder of length-bucketed
    # prefill_{T} variants: the validation pipeline packs rollouts into
    # the cheapest artifact covering each length bucket
    # (ModelSpec::prefill_artifact_for), so short rollouts cost T/max_seq
    # of the full frame's device FLOPs instead of just saving host-side
    # padding. Rows are causal and independent; a bucketed frame differs
    # from the full one only by kernel-shape fp rounding, which the
    # TOPLOC tolerances absorb.
    def prefill_fn(*args):
        n = len(pspecs)
        params = list(args[:n])
        (tokens,) = args[n:]
        return M.prefill(cfg, params, tokens)

    for t_b in prefill_ladder(t) + [t]:
        name = "prefill" if t_b == t else f"prefill_{t_b}"
        defs.append((
            name, prefill_fn,
            pspecs + [_spec((bi, t_b), jnp.int32)],
            _sig(psig + [("tokens", (bi, t_b), "i32")]),
            _sig([("logits", (bi, t_b, v), "f32"),
                  ("hidden", (bi, t_b, d), "f32")]),
        ))

    # --- decode_step (vectored per-lane positions) ---
    # pos is i32[B]: under the continuous-batching scheduler each lane
    # advances independently (retire on EOS, refill with a fresh prompt),
    # so lanes are not position-synchronized. The static reference path
    # passes a constant vector.
    def dec_fn(*args):
        n = len(pspecs)
        params = list(args[:n])
        kv, tok, pos = args[n:]
        return M.decode_step(cfg, params, kv, tok, pos)

    kvs = M.kv_shape(cfg)
    defs.append((
        "decode_step", dec_fn,
        pspecs + [_spec(kvs), _spec((bi,), jnp.int32),
                  _spec((bi,), jnp.int32)],
        _sig(psig + [("kv", kvs, "f32"), ("tok", (bi,), "i32"),
                     ("pos", (bi,), "i32")]),
        _sig([("logits", (bi, v), "f32"), ("hidden", (bi, d), "f32"),
              ("kv", kvs, "f32")]),
    ))

    # --- prefill_kv ladder (continuous-batching prompt prefill) ---
    # One bucketed call prefills up to bi unique prompts straight into the
    # decode KV cache: an L-token prompt costs one prefill_kv_{T} call
    # (smallest T >= L) instead of L decode steps, and lane_src replicates
    # a GRPO group's shared prompt forward across its lanes. Unlike the
    # validator's prefill_{T} ladder this includes the full frame, since
    # prompts up to max_seq-1 must be coverable.
    def prefill_kv_fn(*args):
        n = len(pspecs)
        params = list(args[:n])
        kv, tokens, lane_src, lane_mask = args[n:]
        return M.prefill_kv(cfg, params, kv, tokens, lane_src, lane_mask)

    for t_b in prefill_ladder(t) + [t]:
        defs.append((
            f"prefill_kv_{t_b}", prefill_kv_fn,
            pspecs + [_spec(kvs), _spec((bi, t_b), jnp.int32),
                      _spec((bi,), jnp.int32), _spec((bi,))],
            _sig(psig + [("kv", kvs, "f32"), ("tokens", (bi, t_b), "i32"),
                         ("lane_src", (bi,), "i32"),
                         ("lane_mask", (bi,), "f32")]),
            _sig([("logits", (bi, t_b, v), "f32"),
                  ("hidden", (bi, t_b, d), "f32"), ("kv", kvs, "f32")]),
        ))

    # --- standalone Pallas attention demo (composability proof) ---
    if cfg.name == "nano":
        qs = (2, cfg.n_heads, t, cfg.d_head)
        defs.append((
            "attn_demo",
            lambda q, k, v_: (M.attn_demo(cfg, q, k, v_),),
            [_spec(qs)] * 3,
            _sig([("q", qs, "f32"), ("k", qs, "f32"), ("v", qs, "f32")]),
            _sig([("out", qs, "f32")]),
        ))

    return defs


def lower_size(cfg: C.ModelConfig, out_dir: str, skip_existing: bool):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "model": cfg.to_dict(),
        "n_params": cfg.n_params(),
        "param_specs": [{"name": n, "shape": list(s)}
                        for n, s in cfg.param_specs()],
        "special_tokens": {"pad": C.PAD_ID, "bos": C.BOS_ID, "eos": C.EOS_ID},
        "adam": {"b1": C.ADAM_B1, "b2": C.ADAM_B2, "eps": C.ADAM_EPS},
        "hp_layout": ["lr", "grad_clip", "eps", "delta", "kl_coef",
                      "ent_coef", "reserved0", "reserved1"],
        "toploc": {"interval": C.TOPLOC_INTERVAL, "topk": C.TOPLOC_TOPK},
        "metrics_layout": ["loss", "gnorm", "clipfrac", "entropy", "kl",
                           "ratio_max", "obj_mean"],
        "artifacts": {},
    }
    for name, fn, args, in_sig, out_sig in artifact_defs(cfg):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt", "inputs": in_sig, "outputs": out_sig,
        }
        if skip_existing and os.path.exists(path):
            print(f"  [skip] {cfg.name}/{name}")
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [ok] {cfg.name}/{name}: {len(text)} chars "
              f"({time.time() - t0:.1f}s)")
    with open(os.path.join(out_dir, "spec.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="nano,micro,small")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    for size in args.sizes.split(","):
        cfg = C.SIZES[size]
        print(f"[aot] lowering {size} "
              f"({cfg.n_params() / 1e6:.2f}M params)")
        lower_size(cfg, os.path.join(args.out_dir, size), args.skip_existing)
    print("[aot] done")


if __name__ == "__main__":
    main()
