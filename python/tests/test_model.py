"""L2 correctness: model shapes, packing mask, KV-cache decode vs prefill,
optimizer steps actually learn, GRPO step invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import config as C, model as M

CFG = C.SIZES["nano"]


@pytest.fixture(scope="module")
def params():
    return [np.asarray(p) for p in M.init_params(CFG, jnp.uint32(42))]


def test_param_specs_match_init(params):
    specs = CFG.param_specs()
    assert len(params) == len(specs)
    for p, (name, shape) in zip(params, specs):
        assert p.shape == shape, name
    assert CFG.n_params() == sum(p.size for p in params)


def test_init_deterministic():
    a = M.init_params(CFG, jnp.uint32(7))
    b = M.init_params(CFG, jnp.uint32(7))
    c = M.init_params(CFG, jnp.uint32(8))
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, c))


def test_forward_shapes(params):
    tokens = np.ones((2, 32), np.int32)
    segs = np.ones((2, 32), np.int32)
    logits, hidden = M.forward(CFG, params, tokens, segs)
    assert logits.shape == (2, 32, CFG.vocab)
    assert hidden.shape == (2, 32, CFG.d_model)


def test_packing_equals_separate_sequences(params):
    """Two sequences packed into one row (block-diagonal mask) produce the
    same logprobs as the same sequences run unpacked — the §4.1 integrity
    claim ("maintaining the integrity of the cross entropy calculations")."""
    rng = np.random.default_rng(0)
    a = rng.integers(3, CFG.vocab, 24).astype(np.int32)
    b = rng.integers(3, CFG.vocab, 40).astype(np.int32)

    packed = np.zeros((1, 64), np.int32)
    packed[0, :24] = a
    packed[0, 24:64] = b
    segs = np.zeros((1, 64), np.int32)
    segs[0, :24] = 1
    segs[0, 24:64] = 2
    lp_packed, _, valid = M.token_logprobs(CFG, params, packed, segs)

    sep = np.zeros((2, 64), np.int32)
    sep[0, :24] = a
    sep[1, :40] = b
    seg_sep = np.zeros((2, 64), np.int32)
    seg_sep[0, :24] = 1
    seg_sep[1, :40] = 1
    lp_sep, _, _ = M.token_logprobs(CFG, params, sep, seg_sep)

    lp_packed = np.asarray(lp_packed)
    lp_sep = np.asarray(lp_sep)
    assert_allclose(lp_packed[0, 1:24], lp_sep[0, 1:24], rtol=2e-4, atol=2e-5)
    assert_allclose(lp_packed[0, 25:64], lp_sep[1, 1:40], rtol=2e-4, atol=2e-5)
    # Boundary position (first token of segment 2) must be invalid.
    assert not np.asarray(valid)[0, 24]


def test_decode_matches_prefill(params):
    """KV-cache single-token decode reproduces full-sequence forward
    numerics — the L2 perf optimization is exact, not approximate."""
    rng = np.random.default_rng(1)
    b, t = CFG.batch_infer, 48
    tokens = rng.integers(3, CFG.vocab, (b, t)).astype(np.int32)

    full = np.zeros((b, CFG.max_seq), np.int32)
    full[:, :t] = tokens
    logits_pre, hidden_pre = M.prefill(CFG, params, full)

    kv = jnp.zeros(M.kv_shape(CFG), jnp.float32)
    logits_steps, hidden_steps = [], []
    for pos in range(t):
        # Vectored per-lane positions (constant vector = the old lockstep).
        posv = jnp.full((b,), pos, jnp.int32)
        lg, hd, kv = M.decode_step(CFG, params, kv, tokens[:, pos], posv)
        logits_steps.append(np.asarray(lg))
        hidden_steps.append(np.asarray(hd))

    logits_pre = np.asarray(logits_pre)
    hidden_pre = np.asarray(hidden_pre)
    for pos in range(t):
        assert_allclose(logits_steps[pos], logits_pre[:, pos], rtol=2e-4,
                        atol=2e-4)
        assert_allclose(hidden_steps[pos], hidden_pre[:, pos], rtol=2e-4,
                        atol=2e-4)


def test_decode_lanes_are_independent(params):
    """Per-lane positions: a lane's outputs depend only on its own history,
    not on where other lanes happen to be (the continuous scheduler's
    correctness premise)."""
    rng = np.random.default_rng(2)
    b = CFG.batch_infer
    seq = rng.integers(3, CFG.vocab, 12).astype(np.int32)

    # Reference: all lanes march in lockstep over the same sequence.
    kv = jnp.zeros(M.kv_shape(CFG), jnp.float32)
    ref = []
    for pos in range(len(seq)):
        lg, _, kv = M.decode_step(CFG, params, kv,
                                  jnp.full((b,), seq[pos], jnp.int32),
                                  jnp.full((b,), pos, jnp.int32))
        ref.append(np.asarray(lg)[0])

    # Staggered: lane 0 runs the sequence; lane 1 starts 3 steps late and
    # is fed PAD/pos-0 garbage before that (what idle lanes receive).
    kv = jnp.zeros(M.kv_shape(CFG), jnp.float32)
    lag = 3
    out0, out1 = [], []
    for step in range(len(seq) + lag):
        tok = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        if step < len(seq):
            tok[0], pos[0] = seq[step], step
        if step >= lag:
            tok[1], pos[1] = seq[step - lag], step - lag
        lg, _, kv = M.decode_step(CFG, params, kv, jnp.asarray(tok),
                                  jnp.asarray(pos))
        if step < len(seq):
            out0.append(np.asarray(lg)[0])
        if step >= lag:
            out1.append(np.asarray(lg)[1])

    for pos in range(len(seq)):
        assert_allclose(out0[pos], ref[pos], rtol=1e-5, atol=1e-5)
        assert_allclose(out1[pos], ref[pos], rtol=2e-4, atol=2e-4)


def test_prefill_kv_matches_decode_and_respects_lanes(params):
    """prefill_kv: (1) prompt-position logits/hidden match token-by-token
    decode; (2) decode continues seamlessly from the installed cache;
    (3) unmasked lanes' caches are untouched; (4) lane_src replicates one
    computed row across several lanes (group sharing)."""
    rng = np.random.default_rng(3)
    b = CFG.batch_infer
    tb = 32
    plen = 9
    prompt = rng.integers(3, CFG.vocab, plen).astype(np.int32)

    # Reference: feed the prompt token by token.
    kv_ref = jnp.zeros(M.kv_shape(CFG), jnp.float32)
    ref_logits = []
    for pos in range(plen):
        lg, _, kv_ref = M.decode_step(CFG, params, kv_ref,
                                      jnp.full((b,), prompt[pos], jnp.int32),
                                      jnp.full((b,), pos, jnp.int32))
        ref_logits.append(np.asarray(lg)[0])

    # prefill_kv: unique row 0 = the prompt, installed into lanes 0 and 1
    # (group sharing), lane 2+ masked out; pre-poison lane 2's cache to
    # prove masking preserves it.
    tokens = np.zeros((b, tb), np.int32)
    tokens[0, :plen] = prompt
    kv0 = jnp.zeros(M.kv_shape(CFG), jnp.float32)
    kv0 = kv0.at[:, :, 2].set(7.25)
    lane_src = np.zeros(b, np.int32)
    lane_mask = np.zeros(b, np.float32)
    lane_mask[0] = lane_mask[1] = 1.0
    lg, hd, kv1 = M.prefill_kv(CFG, params, kv0, jnp.asarray(tokens),
                               jnp.asarray(lane_src), jnp.asarray(lane_mask))
    lg, hd, kv1 = np.asarray(lg), np.asarray(hd), np.asarray(kv1)

    for pos in range(plen):
        assert_allclose(lg[0, pos], ref_logits[pos], rtol=2e-4, atol=2e-4)
    # Group sharing: lanes 0 and 1 received identical prompt KV.
    assert np.array_equal(kv1[:, :, 0, :plen], kv1[:, :, 1, :plen])
    # Masked lane untouched.
    assert np.array_equal(kv1[:, :, 2], np.asarray(kv0)[:, :, 2])
    # Installed KV matches the decode-built reference cache.
    assert_allclose(kv1[:, :, 0, :plen], np.asarray(kv_ref)[:, :, 0, :plen],
                    rtol=2e-4, atol=2e-4)

    # Decode continues from the installed cache as if the prompt had been
    # fed token by token: next-step logits agree with the reference path.
    nxt = np.zeros(b, np.int32)
    nxt[0] = nxt[1] = 5
    pos = np.zeros(b, np.int32)
    pos[0] = pos[1] = plen
    lg_cont, _, _ = M.decode_step(CFG, params, jnp.asarray(kv1),
                                  jnp.asarray(nxt), jnp.asarray(pos))
    lg_ref, _, _ = M.decode_step(CFG, params, kv_ref,
                                 jnp.full((b,), 5, jnp.int32),
                                 jnp.full((b,), plen, jnp.int32))
    assert_allclose(np.asarray(lg_cont)[0], np.asarray(lg_ref)[0],
                    rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(lg_cont)[1], np.asarray(lg_ref)[0],
                    rtol=2e-4, atol=2e-4)


def test_pretrain_learns(params):
    """A few pretrain steps on a repeated pattern reduce loss."""
    m = [np.zeros_like(p) for p in params]
    v = [np.zeros_like(p) for p in params]
    ps = [np.asarray(p) for p in params]
    b, t = CFG.batch_train, CFG.max_seq
    tokens = np.tile(np.arange(3, 11, dtype=np.int32), (b, t // 8 + 1))[:, :t]
    segs = np.ones((b, t), np.int32)
    hp = np.array([1e-2, 1.0], np.float32)

    losses = []
    n = len(ps)
    for step in range(8):
        out = M.pretrain_step(CFG, ps, m, v, jnp.float32(step), tokens, segs,
                              hp)
        ps = [np.asarray(x) for x in out[:n]]
        m = [np.asarray(x) for x in out[n:2 * n]]
        v = [np.asarray(x) for x in out[2 * n:3 * n]]
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_grpo_step_at_ratio_one(params):
    """With old_lp = current lp: ratio==1, clipfrac==0, kl==0; gradient is
    still the REINFORCE direction (advantage-weighted)."""
    m = [np.zeros_like(p) for p in params]
    v = [np.zeros_like(p) for p in params]
    b, t = CFG.batch_train, CFG.max_seq
    rng = np.random.default_rng(5)
    tokens = rng.integers(3, CFG.vocab, (b, t)).astype(np.int32)
    segs = np.ones((b, t), np.int32)
    lm = np.ones((b, t), np.float32)
    lm[:, 0] = 0
    adv = rng.normal(0, 1, (b, t)).astype(np.float32)
    lp, _, _ = M.token_logprobs(CFG, params, tokens, segs)
    hp = np.array([3e-4, 0.1, 0.2, 4.0, 0.001, 1e-4, 0, 0], np.float32)
    out = M.grpo_step(CFG, params, m, v, jnp.float32(0), tokens, segs, lm,
                      adv, np.asarray(lp), hp)
    metrics = np.asarray(out[-1])
    loss, gnorm, clipfrac, ent, kl, ratio_max, obj_mean = metrics
    assert clipfrac == 0.0
    assert abs(kl) < 1e-5
    assert abs(ratio_max - 1.0) < 1e-5
    assert gnorm > 0.0
    assert np.isfinite(loss)
    # params moved
    n = len(params)
    moved = sum(float(np.abs(np.asarray(out[i]) - params[i]).max())
                for i in range(n))
    assert moved > 0.0


def test_grpo_metrics_layout_matches_spec():
    from compile.aot import artifact_defs
    defs = {d[0]: d for d in artifact_defs(CFG)}
    out_sig = defs["grpo_step"][4]
    assert out_sig[-1]["name"] == "metrics"
    assert out_sig[-1]["shape"] == [7]
