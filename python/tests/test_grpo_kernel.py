"""L1 correctness: fused GRPO Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, and hyperparameters; assert_allclose
against kernels/ref.py. This is the core correctness signal the Rust runtime
relies on (the same kernel is lowered into grpo_step.hlo.txt).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import grpo_loss, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _inputs(rng, b, t):
    lp_old = rng.uniform(-6.0, -0.05, (b, t)).astype(np.float32)
    lp_new = (lp_old + rng.normal(0.0, 0.7, (b, t))).astype(np.float32)
    lp_new = np.minimum(lp_new, 0.0)
    adv = rng.normal(0.0, 1.5, (b, t)).astype(np.float32)
    mask = (rng.random((b, t)) > 0.25).astype(np.float32)
    return lp_new, lp_old, adv, mask


@settings(**SETTINGS)
@given(
    b=st.integers(1, 9),
    t=st.sampled_from([8, 64, 128, 256, 300]),
    eps=st.sampled_from([0.1, 0.2, 0.3]),
    delta=st.sampled_from([2.0, 4.0, 8.0]),
    block_rows=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_forward_matches_ref(b, t, eps, delta, block_rows, seed):
    rng = np.random.default_rng(seed)
    lp_new, lp_old, adv, mask = _inputs(rng, b, t)
    o_k, c_k, r_k = grpo_loss.grpo_stats(lp_new, lp_old, adv, mask, eps,
                                         delta, block_rows=block_rows)
    o_r, c_r, r_r = ref.grpo_objective_ref(lp_new, lp_old, adv, mask, eps,
                                           delta)
    assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=1e-6, atol=1e-6)
    assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=0, atol=0)
    assert_allclose(np.asarray(r_k), np.asarray(r_r), rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 6),
    t=st.sampled_from([16, 128, 256]),
    eps=st.sampled_from([0.2, 0.3]),
    delta=st.sampled_from([2.0, 4.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_backward_matches_analytic_ref(b, t, eps, delta, seed):
    rng = np.random.default_rng(seed)
    lp_new, lp_old, adv, mask = _inputs(rng, b, t)

    def total(lp):
        return jnp.sum(grpo_loss.grpo_objective(lp, lp_old, adv, mask, eps,
                                                delta))

    g_k = jax.grad(total)(lp_new)
    g_r = ref.grpo_grad_ref(lp_new, lp_old, adv, mask, eps, delta)
    assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-5, atol=1e-6)


def test_backward_matches_autodiff_of_ref():
    """The analytic gradient agrees with jax.grad of the jnp objective
    (verifying the branch-gate derivation in DESIGN.md)."""
    rng = np.random.default_rng(7)
    lp_new, lp_old, adv, mask = _inputs(rng, 8, 256)
    g_a = ref.grpo_grad_ref(lp_new, lp_old, adv, mask, 0.2, 4.0)
    g_d = ref.grpo_grad_autodiff_ref(lp_new, lp_old, adv, mask, 0.2, 4.0)
    assert_allclose(np.asarray(g_a), np.asarray(g_d), rtol=1e-5, atol=1e-6)


def test_two_sided_clip_caps_negative_advantage():
    """Paper §3.4: with A<0 and huge ratio, the delta cap bounds the
    objective at delta*A; one-sided clipping would grow without bound."""
    lp_old = np.full((1, 128), -8.0, np.float32)
    lp_new = np.full((1, 128), -0.5, np.float32)  # ratio ~ e^7.5 >> delta
    adv = np.full((1, 128), -1.0, np.float32)
    mask = np.ones((1, 128), np.float32)
    obj, clip_ind, ratio = grpo_loss.grpo_stats(lp_new, lp_old, adv, mask,
                                                0.2, 4.0)
    assert np.all(np.asarray(obj) == -4.0)  # delta * A
    assert np.all(np.asarray(clip_ind) == 1.0)
    # And the gradient is gated to zero: no runaway update.
    g = jax.grad(lambda l: jnp.sum(
        grpo_loss.grpo_objective(l, lp_old, adv, mask, 0.2, 4.0)))(lp_new)
    assert np.all(np.asarray(g) == 0.0)


def test_faulty_variant_drops_positive_gate():
    """Fig 11 fault model: the faulty kernel keeps pushing A>0 ratios past
    1+eps (nonzero gradient where the correct kernel is gated to zero)."""
    lp_old = np.full((1, 128), -3.0, np.float32)
    lp_new = np.full((1, 128), -1.0, np.float32)  # ratio = e^2 > 1.2
    adv = np.full((1, 128), 1.0, np.float32)
    mask = np.ones((1, 128), np.float32)

    def tot(fn, lp):
        return jnp.sum(fn(lp, lp_old, adv, mask,
                          jnp.zeros(8).at[0].set(0.2).at[1].set(4.0)))

    good = grpo_loss.objective_fn(8, False)
    bad = grpo_loss.objective_fn(8, True)
    g_good = jax.grad(lambda l: tot(good, l))(lp_new)
    g_bad = jax.grad(lambda l: tot(bad, l))(lp_new)
    assert np.all(np.asarray(g_good) == 0.0)
    assert np.all(np.asarray(g_bad) > 0.0)


def test_zero_advantage_gives_zero_signal():
    """Online-filtering rationale (§3.3.2): all-same-reward groups have zero
    advantage => zero objective and zero gradient."""
    rng = np.random.default_rng(3)
    lp_new, lp_old, _, mask = _inputs(rng, 4, 64)
    adv = np.zeros((4, 64), np.float32)
    obj, _, _ = grpo_loss.grpo_stats(lp_new, lp_old, adv, mask, 0.2, 4.0)
    assert np.all(np.asarray(obj) == 0.0)
    g = jax.grad(lambda l: jnp.sum(
        grpo_loss.grpo_objective(l, lp_old, adv, mask, 0.2, 4.0)))(lp_new)
    assert np.all(np.asarray(g) == 0.0)


@pytest.mark.parametrize("n", [1, 127, 128, 129, 1000])
def test_padding_is_exact(n):
    """Non-multiple-of-lane sizes are zero-padded, never corrupted."""
    rng = np.random.default_rng(n)
    lp_new, lp_old, adv, mask = _inputs(rng, 1, n)
    o_k, _, _ = grpo_loss.grpo_stats(lp_new, lp_old, adv, mask, 0.2, 4.0)
    o_r, _, _ = ref.grpo_objective_ref(lp_new, lp_old, adv, mask, 0.2, 4.0)
    assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=1e-6, atol=1e-6)
