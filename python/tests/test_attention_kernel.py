"""L1 correctness: blocked Pallas attention kernel vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention, ref

SETTINGS = dict(max_examples=20, deadline=None)


def _qkv(rng, b, h, t, dh, dtype):
    shape = (b, h, t, dh)
    q = rng.normal(0, 1, shape).astype(dtype)
    k = rng.normal(0, 1, shape).astype(dtype)
    v = rng.normal(0, 1, shape).astype(dtype)
    return q, k, v


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([128, 256, 384]),
    dh=st.sampled_from([16, 32, 64]),
    block_q=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_causal_matches_ref(b, h, t, dh, block_q, seed):
    if t % block_q != 0:
        block_q = t  # degenerate single-block case still exercises the loop
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, b, h, t, dh, np.float32)
    out = attention.mha(q, k, v, block_q=block_q, block_k=128)
    exp = ref.attention_ref(q, k, v, causal=True)
    assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([np.float32, np.float16]))
def test_dtypes(seed, dtype):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, 2, 2, 128, 32, dtype)
    out = attention.mha(q, k, v)
    exp = ref.attention_ref(q.astype(np.float32), k.astype(np.float32),
                            v.astype(np.float32), causal=True)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    assert out.dtype == dtype
    assert_allclose(np.asarray(out, np.float32), np.asarray(exp),
                    rtol=tol, atol=tol)


def test_non_causal():
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 1, 2, 256, 32, np.float32)
    out = attention.mha(q, k, v, causal=False)
    exp = ref.attention_ref(q, k, v, causal=False)
    assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_block_k_sweep_identical():
    """Online-softmax accumulation is exact across kv block sizes."""
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 1, 1, 256, 32, np.float32)
    outs = [np.asarray(attention.mha(q, k, v, block_q=64, block_k=bk))
            for bk in (32, 64, 128, 256)]
    for o in outs[1:]:
        assert_allclose(o, outs[0], rtol=1e-5, atol=1e-6)
