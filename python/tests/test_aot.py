"""AOT pipeline: lowering produces parseable HLO text and a consistent
spec.json; the artifact signatures match what the Rust runtime will bind."""

import json
import os

import pytest

from compile import aot, config as C

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_artifact_defs_cover_all_entrypoints():
    names = {d[0] for d in aot.artifact_defs(C.SIZES["nano"])}
    assert {"init", "pretrain_step", "grpo_step", "grpo_step_faulty",
            "logprobs", "prefill", "decode_step", "attn_demo"} <= names
    micro = {d[0] for d in aot.artifact_defs(C.SIZES["micro"])}
    assert "grpo_step_faulty" not in micro  # fault variant is nano-only


def test_prefill_ladder_artifacts_emitted():
    cfg = C.SIZES["nano"]
    ladder = aot.prefill_ladder(cfg.max_seq)
    # Powers of two from the TOPLOC interval up to (excluding) max_seq.
    assert ladder == [32, 64, 128]
    defs = {d[0]: d for d in aot.artifact_defs(cfg)}
    for t_b in ladder:
        name, _, args, in_sig, out_sig = defs[f"prefill_{t_b}"]
        # The token input and both outputs are bucket-shaped: device FLOPs
        # scale with T, not max_seq.
        assert in_sig[-1]["shape"] == [cfg.batch_infer, t_b]
        assert out_sig[0]["shape"] == [cfg.batch_infer, t_b, cfg.vocab]
        assert out_sig[1]["shape"] == [cfg.batch_infer, t_b, cfg.d_model]
    # The full frame is still there for lengths past the last bucket.
    assert defs["prefill"][3][-1]["shape"] == [cfg.batch_infer, cfg.max_seq]


def test_prefill_kv_ladder_and_vectored_pos():
    """The continuous-batching contract (rust runtime/scheduler.rs):
    decode_step's pos input is per-lane i32[batch_infer], and a
    prefill_kv_{T} ladder — including the full frame, so any prompt up to
    max_seq-1 is coverable — installs prompt KV with lane routing."""
    cfg = C.SIZES["nano"]
    defs = {d[0]: d for d in aot.artifact_defs(cfg)}
    kvs = list(
        (cfg.n_layers, 2, cfg.batch_infer, cfg.max_seq, cfg.d_model))

    dec_in = defs["decode_step"][3]
    pos = next(e for e in dec_in if e["name"] == "pos")
    assert pos["shape"] == [cfg.batch_infer]  # vectored, not scalar
    assert pos["dtype"] == "i32"

    for t_b in aot.prefill_ladder(cfg.max_seq) + [cfg.max_seq]:
        name, _, args, in_sig, out_sig = defs[f"prefill_kv_{t_b}"]
        assert len(in_sig) == len(args), name
        by_name = {e["name"]: e for e in in_sig}
        assert by_name["kv"]["shape"] == kvs
        assert by_name["tokens"]["shape"] == [cfg.batch_infer, t_b]
        assert by_name["lane_src"]["dtype"] == "i32"
        assert by_name["lane_mask"]["dtype"] == "f32"
        assert by_name["lane_src"]["shape"] == [cfg.batch_infer]
        # Bucket-shaped outputs (device FLOPs scale with T) + the cache.
        assert out_sig[0]["shape"] == [cfg.batch_infer, t_b, cfg.vocab]
        assert out_sig[1]["shape"] == [cfg.batch_infer, t_b, cfg.d_model]
        assert out_sig[2]["shape"] == kvs


def test_signatures_are_complete():
    cfg = C.SIZES["nano"]
    n = len(cfg.param_specs())
    for name, fn, args, in_sig, out_sig in aot.artifact_defs(cfg):
        assert len(in_sig) == len(args), name
        for entry in in_sig + out_sig:
            assert set(entry) == {"name", "shape", "dtype"}
        if name in ("pretrain_step", "grpo_step", "grpo_step_faulty"):
            assert len(in_sig) > 3 * n
            assert [e["name"] for e in out_sig[:n]] == \
                   [f"param:{pn}" for pn, _ in cfg.param_specs()]


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "nano")),
                    reason="run `make artifacts` first")
def test_emitted_artifacts_match_spec():
    with open(os.path.join(ART, "nano", "spec.json")) as f:
        spec = json.load(f)
    assert spec["model"]["name"] == "nano"
    assert spec["toploc"] == {"interval": 32, "topk": 8}
    assert spec["hp_layout"][2:4] == ["eps", "delta"]
    for name, meta in spec["artifacts"].items():
        path = os.path.join(ART, "nano", meta["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, name


def test_hlo_text_is_reparseable():
    """Round-trip the smallest artifact through the HLO text emitter."""
    import jax
    cfg = C.SIZES["nano"]
    defs = {d[0]: d for d in aot.artifact_defs(cfg)}
    name, fn, args, _, _ = defs["logprobs"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert text.startswith("HloModule")
    assert "ROOT" in text
