//! The end-to-end driver (DESIGN.md deliverable (b) / EXPERIMENTS.md §E2E):
//! pretrain a base model, then run the FULL decentralized stack — protocol
//! (ledger, discovery, signed invites, heartbeats), SHARDCAST relays,
//! TOPLOC validation, permissionless inference workers over HTTP — for a
//! real GRPO training run, logging the loss curve and reward trajectory.
//!
//!   cargo run --release --example e2e_train -- --rl-steps 12 --workers 3

use intellect2::config::RunConfig;
use intellect2::coordinator::Swarm;
use intellect2::util::cli::Args;
use intellect2::util::metrics::{render_table, sparkline};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig {
        rl_steps: 10,
        prompts_per_step: 6,
        group_size: 4,
        micro_steps: 3,
        max_new_tokens: 16,
        pretrain_steps: 120,
        n_workers: 3,
        n_relays: 2,
        ..Default::default()
    }
    .apply_args(&args);
    let pretrain_steps = cfg.pretrain_steps;

    println!("== INTELLECT-2 e2e: decentralized GRPO over a {}-worker swarm ==", cfg.n_workers);
    let swarm = Swarm::new(cfg.clone())?;
    println!(
        "model {} ({} params) | {} relays | group {} x {} prompts/step | async via SHARDCAST",
        cfg.model,
        swarm.host.spec().n_params,
        cfg.n_relays,
        cfg.group_size,
        cfg.prompts_per_step
    );
    let t0 = std::time::Instant::now();
    let result = swarm.run(pretrain_steps, false)?;
    let wall = t0.elapsed().as_secs_f64();

    let curve = |name: &str| -> Vec<f64> {
        result.series.get(name).iter().map(|x| x.1).collect()
    };
    let pre = curve("pretrain_loss");
    let reward = curve("task_reward");
    println!("\npretrain loss   {}  {:.3} -> {:.3}", sparkline(&pre), pre.first().unwrap_or(&0.0), pre.last().unwrap_or(&0.0));
    println!("task reward     {}  {:.3} -> {:.3}", sparkline(&reward), reward.first().unwrap_or(&0.0), reward.last().unwrap_or(&0.0));

    println!(
        "\n{}",
        render_table(
            &["step", "broadcast_s", "batch_ready_s", "train_s", "overlap_s"],
            &result.timing_rows()
        )
    );

    println!(
        "submissions: {} received, {} accepted, {} rejected, {} stale | rollouts verified: {} ({} dropped stale) | tokens decoded: {} | slashed: {} | wall {wall:.0}s",
        result.stats.submissions_received.get(),
        result.stats.submissions_accepted.get(),
        result.stats.submissions_rejected.get(),
        result.stats.submissions_stale.get(),
        result.stats.rollouts_verified.get(),
        result.stats.rollouts_dropped_stale.get(),
        result.stats.decode_tokens.get(),
        result.stats.nodes_slashed.get(),
    );
    println!(
        "off-policy staleness of trained rollouts: {}",
        result.stats.staleness_summary()
    );
    assert!(result.ledger.verify_chain(), "ledger audit failed");
    result.series.save("runs/e2e_train.jsonl")?;
    println!("series written to runs/e2e_train.jsonl");
    Ok(())
}
