//! Quickstart: the smallest end-to-end tour of the stack — load the AOT
//! artifacts, pretrain a base model, run a few GRPO steps (deterministic
//! async-2 pipeline), evaluate on a held-out suite.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::tasks::eval::Suite;
use intellect2::util::cli::Args;
use intellect2::util::metrics::sparkline;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig {
        rl_steps: 8,
        pretrain_steps: 60,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 16,
        ..Default::default()
    }
    .apply_args(&args);

    println!("== INTELLECT-2 quickstart ({} model, async-{}) ==", cfg.model, cfg.async_level);
    let pipeline = SyncPipeline::new(cfg.clone())?;
    let mix: Vec<String> = pipeline
        .dataset
        .env_counts()
        .iter()
        .map(|(env, n)| format!("{n} {env}"))
        .collect();
    println!(
        "dataset: {} tasks ({}) | model: {} params",
        pipeline.dataset.len(),
        mix.join(" + "),
        pipeline.host.spec().n_params,
    );

    println!("\n-- pretraining base model ({} steps) --", cfg.pretrain_steps);
    let state = pipeline.bootstrap()?;
    let pre = pipeline.series.get("pretrain_loss");
    println!(
        "loss {:.3} -> {:.3}  {}",
        pre.first().map(|x| x.1).unwrap_or(0.0),
        pre.last().map(|x| x.1).unwrap_or(0.0),
        sparkline(&pre.iter().map(|x| x.1).collect::<Vec<_>>())
    );

    let base = Arc::new(state.params.clone());
    println!("\n-- GRPO reinforcement learning ({} steps) --", cfg.rl_steps);
    let state = pipeline.run_rl(state, cfg.rl_steps, "", false)?;
    let rewards = pipeline.series.get("task_reward");
    println!(
        "task reward {:.3} -> {:.3}  {}",
        rewards.first().map(|x| x.1).unwrap_or(0.0),
        rewards.last().map(|x| x.1).unwrap_or(0.0),
        sparkline(&rewards.iter().map(|x| x.1).collect::<Vec<_>>())
    );

    println!("\n-- held-out evaluation (MATH-HARD suite) --");
    let tuned = Arc::new(state.params.clone());
    let suite = Suite::math_hard();
    let before = pipeline.evaluate_suite(&base, &suite, 16)?;
    let after = pipeline.evaluate_suite(&tuned, &suite, 16)?;
    println!("base: {before:.1}%   RL-trained: {after:.1}%");

    pipeline.series.save("runs/quickstart.jsonl")?;
    println!("\nseries written to runs/quickstart.jsonl");
    Ok(())
}
