//! Length-controlled reasoning (paper §3.1.2): train with the discrete
//! thinking-budget rewards (TARGET-SHORT analogue) and show the length
//! penalty trending down while task reward climbs.
//!
//!   cargo run --release --example length_control -- --rl-steps 12

use intellect2::config::RunConfig;
use intellect2::coordinator::SyncPipeline;
use intellect2::rl::reward::RewardConfig;
use intellect2::util::cli::Args;
use intellect2::util::metrics::sparkline;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig {
        rl_steps: 10,
        pretrain_steps: 80,
        prompts_per_step: 4,
        group_size: 4,
        micro_steps: 2,
        max_new_tokens: 72,
        reward: RewardConfig::target_short(),
        ..Default::default()
    }
    .apply_args(&args);

    println!(
        "== length control: targets {:?}, alpha {} ==",
        cfg.reward.targets, cfg.reward.alpha
    );
    let pipeline = SyncPipeline::new(cfg.clone())?;
    let state = pipeline.bootstrap()?;
    let _state = pipeline.run_rl(state, cfg.rl_steps, "", false)?;

    for name in ["task_reward", "length_penalty", "completion_len"] {
        let xs: Vec<f64> = pipeline.series.smoothed(name, 3).iter().map(|x| x.1).collect();
        println!(
            "{name:<16} {}  {:.3} -> {:.3}",
            sparkline(&xs),
            xs.first().unwrap_or(&0.0),
            xs.last().unwrap_or(&0.0)
        );
    }
    pipeline.series.save("runs/length_control.jsonl")?;
    println!("series written to runs/length_control.jsonl");
    Ok(())
}
