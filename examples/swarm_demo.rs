//! Protocol + trust demo: a permissionless swarm with one adversarial
//! worker. Shows discovery -> signed invite -> heartbeats, SHARDCAST
//! distribution, and TOPLOC catching the cheater (reward tampering),
//! slashing it on the ledger and evicting it from the pool.
//!
//!   cargo run --release --example swarm_demo

use intellect2::config::RunConfig;
use intellect2::coordinator::Swarm;
use intellect2::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = RunConfig {
        rl_steps: 3,
        prompts_per_step: 3,
        group_size: 3,
        micro_steps: 1,
        max_new_tokens: 12,
        pretrain_steps: 40,
        n_workers: 2,
        n_relays: 2,
        ..Default::default()
    }
    .apply_args(&args);

    println!("== swarm demo: 2 honest workers + 1 reward-tampering worker ==");
    let swarm = Swarm::new(cfg)?;
    let result = swarm.run(40, /*evil_worker=*/ true)?;

    println!("\nledger audit: chain valid = {}", result.ledger.verify_chain());
    println!("entries on ledger: {}", result.ledger.len());
    let slashed: Vec<String> = result
        .ledger
        .entries()
        .iter()
        .filter_map(|e| match &e.tx {
            intellect2::protocol::Tx::Slash { node, reason, .. } => {
                Some(format!("  node {node:#x} slashed: {reason}"))
            }
            _ => None,
        })
        .collect();
    println!("slash events ({}):", slashed.len());
    for s in &slashed {
        println!("{s}");
    }
    assert!(
        result.stats.nodes_slashed.get() >= 1,
        "the adversarial worker should have been slashed"
    );
    println!(
        "\nhonest pipeline unaffected: {} rollouts verified, {} submissions rejected \
         ({} unattributable, not slashed), {} stale submissions dropped",
        result.stats.rollouts_verified.get(),
        result.stats.submissions_rejected.get(),
        result.stats.submissions_unattributed.get(),
        result.stats.submissions_stale.get()
    );
    println!(
        "staleness of trained rollouts (window k={}): {} | dropped stale: {}",
        swarm.cfg.async_level,
        result.stats.staleness_summary(),
        result.stats.rollouts_dropped_stale.get()
    );
    Ok(())
}
